//! Functional (bit-exact) model of one SwiftTron encoder layer.
//!
//! Mirrors `python/compile/model.py::quant_encoder_layer` operation for
//! operation using the `quant` primitives; the integration tests check it
//! against the PJRT-executed Pallas artifact bit-for-bit (the same
//! software-vs-RTL triangle the paper validates with QuestaSim).
//!
//! Besides numerics it returns the data-dependent LayerNorm sqrt
//! iteration counts, which the cycle-accurate simulator can consume when
//! `worst_case_sqrt = false`.
//!
//! Two entry styles (DESIGN.md §6):
//!
//! * the allocating convenience wrappers [`layer_forward`] /
//!   [`encoder_forward`], which build a fresh [`Workspace`] per call and
//!   keep the pre-refactor signatures, and
//! * the workspace path [`layer_forward_ws`] / [`encoder_forward_ws`],
//!   which runs over a caller-owned [`Workspace`] arena and a live
//!   sequence length `m_eff <= geo.m` — after warm-up it performs zero
//!   heap allocations per call (asserted by
//!   `rust/tests/workspace_alloc.rs`) and both paths are bit-exact with
//!   each other for every input (`rust/tests/variable_length.rs`).
//!
//! The workspace path runs the *fused, head-parallel* attention core
//! (DESIGN.md §7): the INT32 -> INT8 requantization rides the matmul
//! readout as an [`Epilogue`] instead of separate full-tensor passes,
//! and every head owns a disjoint lane of the arena so the head loop is
//! a scoped parallel-for (gated by [`ATTN_PAR_MIN_MACS`] and the
//! `attn_heads_parallel` knob, `HwConfig` -> `FunctionalEngine` ->
//! [`Workspace::set_attn_heads_parallel`]).  The pre-fusion serial
//! structure survives as [`layer_forward_ws_unfused`] — the golden
//! reference `rust/tests/attention_fused.rs` asserts the fused path
//! bit-exact against on randomized shapes.

use crate::model::{Geometry, LayerConsts};
use crate::quant::{
    self, bias_int4, i_layernorm, i_matmul_bt, i_matmul_bt_par, i_matmul_epilogue,
    i_matmul_epilogue_par, i_matmul_int4_epilogue_par, i_matmul_int4_par, i_matmul_par,
    i_softmax, int4_from_int8, int4_readout_dyadic, pack_int4, requantize, requantize_signed,
    rescale, Dyadic, Epilogue, GeluConsts, LayerNormConsts, SoftmaxConsts, INT4_SHIFT,
};
use crate::util::rng::Rng;
use crate::util::threadpool::run_scoped;
use std::collections::BTreeMap;

/// One layer's integer weights, row-major (see aot.py WEIGHT_KEYS).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: Vec<i32>,
    pub bq: Vec<i32>,
    pub wk: Vec<i32>,
    pub bk: Vec<i32>,
    pub wv: Vec<i32>,
    pub bv: Vec<i32>,
    pub wo: Vec<i32>,
    pub bo: Vec<i32>,
    pub w1: Vec<i32>,
    pub b1: Vec<i32>,
    pub w2: Vec<i32>,
    pub b2: Vec<i32>,
    pub gamma1: Vec<i32>,
    pub beta1: Vec<i32>,
    pub gamma2: Vec<i32>,
    pub beta2: Vec<i32>,
}

impl LayerWeights {
    pub fn from_blob(
        blob: &crate::model::Blob,
        layer: usize,
    ) -> Result<LayerWeights, String> {
        let g = |k: &str| blob.i32(&format!("L{layer}.{k}"));
        Ok(LayerWeights {
            wq: g("wq")?, bq: g("bq")?, wk: g("wk")?, bk: g("bk")?,
            wv: g("wv")?, bv: g("bv")?, wo: g("wo")?, bo: g("bo")?,
            w1: g("w1")?, b1: g("b1")?, w2: g("w2")?, b2: g("b2")?,
            gamma1: g("gamma1")?, beta1: g("beta1")?,
            gamma2: g("gamma2")?, beta2: g("beta2")?,
        })
    }

    /// Synthetic INT8-range weights with the same shapes `from_blob`
    /// loads, deterministic in `rng` — the artifact-free model used by
    /// `coordinator::FunctionalEngine`, the serving-scaling bench, and
    /// the functional tests.
    pub fn synthetic(rng: &mut Rng, geo: &Geometry) -> LayerWeights {
        let (d, dff) = (geo.d, geo.d_ff);
        let mut w = |n: usize, lim: i64| -> Vec<i32> {
            (0..n).map(|_| rng.range_i64(-lim, lim) as i32).collect()
        };
        LayerWeights {
            wq: w(d * d, 127), bq: w(d, 1000),
            wk: w(d * d, 127), bk: w(d, 1000),
            wv: w(d * d, 127), bv: w(d, 1000),
            wo: w(d * d, 127), bo: w(d, 1000),
            w1: w(d * dff, 127), b1: w(dff, 1000),
            w2: w(dff * d, 127), b2: w(d, 1000),
            gamma1: w(d, 127), beta1: w(d, 500),
            gamma2: w(d, 127), beta2: w(d, 500),
        }
    }
}

/// One layer's weights on the packed INT4 grid (DESIGN.md §14): the
/// projection and FFN weight matrices quantized to nibbles at 1/16 of
/// the INT8 scale and packed two-per-byte along `k`
/// ([`crate::quant::pack_int4`]), their biases on the matching
/// accumulator scale ([`crate::quant::bias_int4`]).  LayerNorm's
/// gamma/beta stay full-precision copies — they feed the elementwise
/// affine, not the MAC array's weight port.  Quantized *from* a
/// [`LayerWeights`] bundle, so an INT4 replica group derives from the
/// same shared synthetic model as its INT8 siblings.
#[derive(Clone, Debug)]
pub struct LayerWeightsInt4 {
    pub wq: Vec<u8>,
    pub bq: Vec<i32>,
    pub wk: Vec<u8>,
    pub bk: Vec<i32>,
    pub wv: Vec<u8>,
    pub bv: Vec<i32>,
    pub wo: Vec<u8>,
    pub bo: Vec<i32>,
    pub w1: Vec<u8>,
    pub b1: Vec<i32>,
    pub w2: Vec<u8>,
    pub b2: Vec<i32>,
    pub gamma1: Vec<i32>,
    pub beta1: Vec<i32>,
    pub gamma2: Vec<i32>,
    pub beta2: Vec<i32>,
}

impl LayerWeightsInt4 {
    /// Quantize an INT8 layer bundle onto the packed INT4 grid
    /// (round-half-up to the nibble range, [`int4_from_int8`]).
    pub fn quantize(w: &LayerWeights, geo: &Geometry) -> LayerWeightsInt4 {
        let (d, dff) = (geo.d, geo.d_ff);
        let pk = |w8: &[i32], k: usize, n: usize| pack_int4(&int4_from_int8(w8), k, n);
        LayerWeightsInt4 {
            wq: pk(&w.wq, d, d),
            bq: bias_int4(&w.bq),
            wk: pk(&w.wk, d, d),
            bk: bias_int4(&w.bk),
            wv: pk(&w.wv, d, d),
            bv: bias_int4(&w.bv),
            wo: pk(&w.wo, d, d),
            bo: bias_int4(&w.bo),
            w1: pk(&w.w1, d, dff),
            b1: bias_int4(&w.b1),
            w2: pk(&w.w2, dff, d),
            b2: bias_int4(&w.b2),
            gamma1: w.gamma1.clone(),
            beta1: w.beta1.clone(),
            gamma2: w.gamma2.clone(),
            beta2: w.beta2.clone(),
        }
    }
}

/// A plausible integer design (dyadic scales, softmax/GELU/LayerNorm
/// constants) for a synthetic layer of geometry `geo` — the values the
/// AOT calibration pass would produce for weights in the
/// [`LayerWeights::synthetic`] range.
///
/// The requantizers are geometry-aware and *non-saturating*: each one
/// maps ~3σ of its accumulator distribution (σ grows as `√d` with the
/// fan-in, as `√m` with the attention span) inside the ±127 rails, the
/// way a calibration pass that histograms real activations would set
/// them.  That keeps the integer datapath proportional instead of
/// rail-clipped, which is what makes the INT4 tier's logit margins
/// informative for cascade escalation (DESIGN.md §14): quantization
/// noise stays a small perturbation, so INT4/INT8 label flips
/// concentrate at small margins where the gate can catch them.
pub fn synthetic_consts(geo: &Geometry) -> LayerConsts {
    let dy = |x: f64| Dyadic::approx16(x);
    let rd = (geo.d as f64).sqrt();
    // qkv/residual accumulators: std ≈ w_std·x_std·√d; target ~40 codes
    let dy_qkv = 0.0114 / rd;
    // context rows: Σp = 127 over an attention span that widens with m
    let dy_ctx = (geo.m as f64 / 8.0).sqrt() / 127.0;
    let dy_res = 0.0171 / rd;
    // GELU epilogue: the erf plateau scales the positive branch by
    // ~8.6e7, on an FFN accumulator of std ≈ 2932·√d
    let dy_gelu = 40.0 / (8.59e7 * 0.58 * 2932.0 * rd);
    LayerConsts {
        dy_q: dy(dy_qkv), dy_k: dy(dy_qkv), dy_v: dy(dy_qkv),
        dy_scale: Dyadic { b: 1, c: 2 },
        dy_ctx: dy(dy_ctx), dy_res1: dy(dy_res),
        dy_ln1: dy(0.0043), dy_gelu: Dyadic::approximate(dy_gelu, 14, 52),
        dy_res2: dy(dy_res), dy_ln2: dy(0.0043),
        softmax: SoftmaxConsts::design(0.0009),
        gelu: GeluConsts::design(0.0004),
        ln1: LayerNormConsts { s_in: 0.02, s_gamma: 0.008, d: geo.d },
        ln2: LayerNormConsts { s_in: 0.02, s_gamma: 0.008, d: geo.d },
        scales: BTreeMap::new(),
    }
}

/// Output of one functional layer evaluation.
pub struct LayerOutput {
    /// INT8-coded activations (stored i32), length m*d, scale `s_out`.
    pub q_out: Vec<i32>,
    /// sqrt iteration counts: ln1 rows then ln2 rows (2*m entries).
    pub sqrt_iters: Vec<u32>,
}

/// Minimum per-head attention work (Q·Kᵀ + P·V = `2 * m² * dh` MACs)
/// for the head-parallel loop to pay for its per-head scoped-thread
/// spawns; below it the head loop stays serial inside the calling
/// thread.  One spawn amortizes over a head's whole
/// MatMul→Scale→Softmax→Requant→MatMul pipeline (softmax included),
/// so the bar sits far below the per-matmul
/// [`crate::quant::PAR_MIN_MACS`]: roberta-scale geometry goes parallel
/// from `m_eff ≈ 32` up, the tiny preset and short requests stay
/// serial.  Swept in EXPERIMENTS.md §Perf (attention leg).
pub const ATTN_PAR_MIN_MACS: usize = 1 << 17;

/// Per-layer scratch buffers, all sized to the construction geometry's
/// maximum sequence length and sliced down to the live `m_eff`.  The
/// attention buffers hold one *lane per head* (`heads × …`), so the
/// head loop can fan out over disjoint `&mut` chunks.
struct LayerScratch {
    geo: Geometry,
    /// Head-parallel attention knob (Workspace setters; DESIGN.md §7).
    attn_heads_parallel: bool,
    /// Per-head MAC floor gating the parallel head loop (tests force 0).
    attn_par_min_macs: usize,
    /// INT32 accumulator for the rescaled output-projection / FFN-out
    /// readouts (their lifetimes never overlap).
    acc: Vec<i32>,
    q8: Vec<i32>,
    k8: Vec<i32>,
    v8: Vec<i32>,
    /// Unfused reference path only: whole-tensor context accumulator.
    ctx_acc: Vec<i32>,
    ctx8: Vec<i32>,
    x2: Vec<i32>,
    /// LayerNorm output rows (ln1 is consumed into `x2` before ln2 runs).
    ln: Vec<i32>,
    /// heads × (m × m) score lanes.
    scores: Vec<i32>,
    /// heads × (m × m) probability lanes.
    probs: Vec<i32>,
    /// heads × m rescaled-score rows.
    row64: Vec<i64>,
    /// heads × (m × dh) gathered Q/K/V head panels and context output.
    qh: Vec<i32>,
    kh: Vec<i32>,
    vh: Vec<i32>,
    ctx_h: Vec<i32>,
    /// Residual rows in i64 (res1 is consumed before res2 is built).
    res: Vec<i64>,
    g64: Vec<i64>,
    b64: Vec<i64>,
    hff: Vec<i32>,
    h8: Vec<i32>,
}

/// Reusable scratch arena for the allocation-free forward pass
/// (DESIGN.md §6).  Every intermediate of [`layer_forward_ws`] and the
/// ping-pong activations of [`encoder_forward_ws`] live here, sized once
/// to `geo` (the maximum sequence length) and sliced per request to the
/// live `m_eff` — a replica keeps one resident and the hot path never
/// touches the allocator.
pub struct Workspace {
    s: LayerScratch,
    act0: Vec<i32>,
    act1: Vec<i32>,
}

impl Workspace {
    /// Build an arena for geometry `geo`; serves any `m_eff` in
    /// `1..=geo.m` for layers matching `geo`'s d / d_ff / heads.  The
    /// head-parallel attention core is on by default, gated by
    /// [`ATTN_PAR_MIN_MACS`] (see the setters).
    pub fn new(geo: &Geometry) -> Workspace {
        let (m, d, dff, dh, heads) = (geo.m, geo.d, geo.d_ff, geo.dh(), geo.heads.max(1));
        Workspace {
            s: LayerScratch {
                geo: *geo,
                attn_heads_parallel: true,
                attn_par_min_macs: ATTN_PAR_MIN_MACS,
                acc: vec![0i32; m * d],
                q8: vec![0i32; m * d],
                k8: vec![0i32; m * d],
                v8: vec![0i32; m * d],
                ctx_acc: vec![0i32; m * d],
                ctx8: vec![0i32; m * d],
                x2: vec![0i32; m * d],
                ln: vec![0i32; m * d],
                scores: vec![0i32; heads * m * m],
                probs: vec![0i32; heads * m * m],
                row64: vec![0i64; heads * m],
                qh: vec![0i32; heads * m * dh],
                kh: vec![0i32; heads * m * dh],
                vh: vec![0i32; heads * m * dh],
                ctx_h: vec![0i32; heads * m * dh],
                res: vec![0i64; m * d],
                g64: vec![0i64; d],
                b64: vec![0i64; d],
                hff: vec![0i32; m * dff],
                h8: vec![0i32; m * dff],
            },
            act0: vec![0i32; m * d],
            act1: vec![0i32; m * d],
        }
    }

    /// Maximum live sequence length this arena can serve.
    pub fn max_seq_len(&self) -> usize {
        self.s.geo.m
    }

    /// Select the head-parallel attention core (default on).  Off
    /// forces the serial head loop — numerics are bit-exact either way
    /// (DESIGN.md §7), so this is an execution knob, not a model knob.
    pub fn set_attn_heads_parallel(&mut self, on: bool) {
        self.s.attn_heads_parallel = on;
    }

    /// Override the per-head MAC floor ([`ATTN_PAR_MIN_MACS`]) below
    /// which the head loop stays serial even when parallelism is
    /// enabled.  Tests force 0 to exercise the scoped parallel-for at
    /// tiny shapes.
    pub fn set_attn_par_min_macs(&mut self, macs: usize) {
        self.s.attn_par_min_macs = macs;
    }
}

/// INT32 -> INT8 requantization of a whole buffer into `out`.
fn requant_into(acc: &[i32], dy: Dyadic, out: &mut [i32]) {
    for (o, &v) in out.iter_mut().zip(acc) {
        *o = requantize(v as i64, dy);
    }
}

/// Extract head `h` (columns h*dh..(h+1)*dh) into `out` (m x dh).
fn gather_head(x: &[i32], m: usize, d: usize, h: usize, dh: usize, out: &mut [i32]) {
    for r in 0..m {
        out[r * dh..(r + 1) * dh]
            .copy_from_slice(&x[r * d + h * dh..r * d + (h + 1) * dh]);
    }
}

/// One attention head of the fused path: gather the head's Q/K/V
/// panels, Q·Kᵀ, per-row Scale → Softmax, then P·V with the
/// INT32 -> INT8 context requantization fused at the matmul readout
/// (paper Fig. 10's per-head pipeline).  Touches only the head's own
/// lanes, so heads run concurrently with no shared mutable state.
/// `par_kernels` selects the auto-dispatching matmuls — off inside
/// spawned head tasks (head-level concurrency already owns the cores),
/// on in the serial head loop so large serial runs keep row tiling.
#[allow(clippy::too_many_arguments)]
fn attention_head_fused(
    h: usize,
    m: usize,
    d: usize,
    dh: usize,
    q8: &[i32],
    k8: &[i32],
    v8: &[i32],
    c: &LayerConsts,
    par_kernels: bool,
    qh: &mut [i32],
    kh: &mut [i32],
    vh: &mut [i32],
    scores: &mut [i32],
    probs: &mut [i32],
    row64: &mut [i64],
    ctx_h: &mut [i32],
) {
    gather_head(q8, m, d, h, dh, qh);
    gather_head(k8, m, d, h, dh, kh);
    gather_head(v8, m, d, h, dh, vh);
    if par_kernels {
        i_matmul_bt_par(qh, kh, m, dh, m, scores);
    } else {
        i_matmul_bt(qh, kh, m, dh, m, scores);
    }
    // Scale block + Softmax rows
    for r in 0..m {
        for (dst, &sv) in row64.iter_mut().zip(&scores[r * m..(r + 1) * m]) {
            *dst = rescale(sv as i64, c.dy_scale);
        }
        i_softmax(row64, &c.softmax, &mut probs[r * m..(r + 1) * m]);
    }
    // P.V with the context requantization fused at readout — the
    // per-head fused rescale ITA/FQ-BERT put at the PE boundary
    let epi = Epilogue::Requant(c.dy_ctx);
    if par_kernels {
        i_matmul_epilogue_par(probs, vh, None, m, m, dh, epi, ctx_h);
    } else {
        i_matmul_epilogue(probs, vh, None, m, m, dh, epi, ctx_h);
    }
}

/// Bit-exact integer encoder layer (paper Figs. 5, 8-15) over the
/// scratch arena — the fused, head-parallel structure (DESIGN.md §7).
/// `m_eff` rows are live; every loop and kernel runs on exactly those
/// rows, so both numerics and cost shape to the request.  Bit-exact
/// with [`layer_forward_scratch_unfused`] for every input: the fused
/// epilogues are elementwise and each head's accumulation order is
/// untouched (asserted in `rust/tests/attention_fused.rs`).
#[allow(clippy::too_many_arguments)]
fn layer_forward_scratch(
    q_x: &[i32],
    w: &LayerWeights,
    c: &LayerConsts,
    geo: &Geometry,
    m_eff: usize,
    s: &mut LayerScratch,
    q_out: &mut [i32],
    sqrt_iters: &mut Vec<u32>,
) {
    let (d, dff, dh, heads) = (geo.d, geo.d_ff, geo.dh(), geo.heads);
    let m = m_eff;
    assert!(
        m >= 1 && m <= s.geo.m && d == s.geo.d && dff == s.geo.d_ff && heads == s.geo.heads,
        "m_eff {m} / geometry incompatible with workspace built for {:?}",
        s.geo
    );
    assert_eq!(q_x.len(), m * d, "q_x shape");
    assert_eq!(q_out.len(), m * d, "q_out shape");

    let attn_parallel =
        s.attn_heads_parallel && heads > 1 && 2 * m * m * dh >= s.attn_par_min_macs;

    let LayerScratch {
        acc, q8, k8, v8, ctx8, x2, ln, scores, probs, row64,
        qh, kh, vh, ctx_h, res, g64, b64, hff, h8, ..
    } = s;
    let acc = &mut acc[..m * d];
    let q8 = &mut q8[..m * d];
    let k8 = &mut k8[..m * d];
    let v8 = &mut v8[..m * d];
    let ctx8 = &mut ctx8[..m * d];
    let x2 = &mut x2[..m * d];
    let ln = &mut ln[..m * d];
    let scores = &mut scores[..heads * m * m];
    let probs = &mut probs[..heads * m * m];
    let row64 = &mut row64[..heads * m];
    let qh = &mut qh[..heads * m * dh];
    let kh = &mut kh[..heads * m * dh];
    let vh = &mut vh[..heads * m * dh];
    let ctx_h = &mut ctx_h[..heads * m * dh];
    let res = &mut res[..m * d];
    let g64 = &mut g64[..d];
    let b64 = &mut b64[..d];
    let hff = &mut hff[..m * dff];
    let h8 = &mut h8[..m * dff];

    // --- Q/K/V projections, requantization fused at the readout (no
    // separate full-tensor pass, no shared INT32 accumulator) ---
    i_matmul_epilogue_par(q_x, &w.wq, Some(&w.bq), m, d, d, Epilogue::Requant(c.dy_q), q8);
    i_matmul_epilogue_par(q_x, &w.wk, Some(&w.bk), m, d, d, Epilogue::Requant(c.dy_k), k8);
    i_matmul_epilogue_par(q_x, &w.wv, Some(&w.bv), m, d, d, Epilogue::Requant(c.dy_v), v8);

    // --- Attention: every head owns disjoint per-head lanes, so the
    // head loop is a scoped parallel-for when the per-head work clears
    // the spawn cost (serial in-thread otherwise / when disabled) ---
    // (dh == 0 is a degenerate geometry: no head owns any columns, the
    // tail fill below zeroes all of ctx8 — and chunks_mut(0) would panic)
    if dh > 0 {
        let (q8, k8, v8) = (&*q8, &*k8, &*v8);
        let lanes = qh
            .chunks_mut(m * dh)
            .zip(kh.chunks_mut(m * dh))
            .zip(vh.chunks_mut(m * dh))
            .zip(ctx_h.chunks_mut(m * dh))
            .zip(scores.chunks_mut(m * m))
            .zip(probs.chunks_mut(m * m))
            .zip(row64.chunks_mut(m))
            .enumerate();
        if attn_parallel {
            let jobs: Vec<_> = lanes
                .map(|(h, ((((((qh, kh), vh), ctx_h), scores), probs), row64))| {
                    move || {
                        attention_head_fused(
                            h, m, d, dh, q8, k8, v8, c, false, qh, kh, vh, scores, probs,
                            row64, ctx_h,
                        )
                    }
                })
                .collect();
            run_scoped(jobs);
        } else {
            for (h, ((((((qh, kh), vh), ctx_h), scores), probs), row64)) in lanes {
                attention_head_fused(
                    h, m, d, dh, q8, k8, v8, c, true, qh, kh, vh, scores, probs, row64,
                    ctx_h,
                );
            }
        }
    }
    // Scatter the INT8 head panels into row-major ctx8.  Tail columns
    // (heads * dh may undershoot d) stay zero exactly as the unfused
    // path's zeroed accumulator guarantees — requantize(0) == 0.
    if heads * dh < d {
        for r in 0..m {
            ctx8[r * d + heads * dh..(r + 1) * d].fill(0);
        }
    }
    if dh > 0 {
        for (h, lane) in ctx_h.chunks(m * dh).enumerate() {
            for r in 0..m {
                ctx8[r * d + h * dh..r * d + (h + 1) * dh]
                    .copy_from_slice(&lane[r * dh..(r + 1) * dh]);
            }
        }
    }

    // --- output projection with the residual-alignment rescale fused
    // at the readout, then the i64 residual add + LayerNorm 1 ---
    i_matmul_epilogue_par(ctx8, &w.wo, Some(&w.bo), m, d, d, Epilogue::Rescale(c.dy_res1), acc);
    for ((dst, &xv), &av) in res.iter_mut().zip(q_x).zip(acc.iter()) {
        *dst = xv as i64 + av as i64;
    }
    for (g, &v) in g64.iter_mut().zip(&w.gamma1) {
        *g = v as i64;
    }
    for (b, &v) in b64.iter_mut().zip(&w.beta1) {
        *b = v as i64;
    }
    for r in 0..m {
        let row = &mut ln[r * d..(r + 1) * d];
        let it = i_layernorm(&res[r * d..(r + 1) * d], g64, b64, &c.ln1, row);
        sqrt_iters.push(it);
    }
    requant_into(ln, c.dy_ln1, x2);

    // --- FFN: MatMul -> GELU -> Req -> MatMul (rescale fused) ---
    i_matmul_par(x2, &w.w1, Some(&w.b1), m, d, dff, hff);
    for (o, &v) in h8.iter_mut().zip(hff.iter()) {
        *o = requantize_signed(quant::i_gelu(v as i64, &c.gelu), c.dy_gelu, -1);
    }
    i_matmul_epilogue_par(h8, &w.w2, Some(&w.b2), m, dff, d, Epilogue::Rescale(c.dy_res2), acc);

    // --- residual align + LayerNorm 2 + output requant ---
    for ((dst, &xv), &av) in res.iter_mut().zip(x2.iter()).zip(acc.iter()) {
        *dst = xv as i64 + av as i64;
    }
    for (g, &v) in g64.iter_mut().zip(&w.gamma2) {
        *g = v as i64;
    }
    for (b, &v) in b64.iter_mut().zip(&w.beta2) {
        *b = v as i64;
    }
    for r in 0..m {
        let row = &mut ln[r * d..(r + 1) * d];
        let it = i_layernorm(&res[r * d..(r + 1) * d], g64, b64, &c.ln2, row);
        sqrt_iters.push(it);
    }
    requant_into(ln, c.dy_ln2, q_out);
}

/// The INT4 twin of [`layer_forward_scratch`] (DESIGN.md §14): the
/// same fused, head-parallel structure with every *weight-stationary*
/// matmul (Q/K/V/output projections, both FFN matmuls) running the
/// packed INT4 kernels.  The 16x-smaller accumulator scale is
/// compensated where the accumulator leaves the array:
/// * requantize/rescale epilogues take the `2^4`-scaled dyadic
///   ([`int4_readout_dyadic`]) — bit-exact with multiplying the
///   accumulator by 16 first ([`Dyadic::scale_pow2`]);
/// * the FFN accumulator feeding the *non-linear* GELU is shifted up
///   by [`INT4_SHIFT`] explicitly (an exact integer multiply), since a
///   polynomial evaluation cannot absorb a scale into its output.
/// Attention (Q·Kᵀ, Softmax, P·V) is activation-activation and runs
/// the identical INT8 core; LayerNorm uses the full-precision
/// gamma/beta copies.  On weights that sit exactly on the INT4 grid
/// (every value a multiple of 16) this path is *bit-identical* to the
/// INT8 path — the golden test below pins that; off-grid weights make
/// it the cascade's cheap, approximate tier.
#[allow(clippy::too_many_arguments)]
fn layer_forward_scratch_int4(
    q_x: &[i32],
    w: &LayerWeightsInt4,
    c: &LayerConsts,
    geo: &Geometry,
    m_eff: usize,
    s: &mut LayerScratch,
    q_out: &mut [i32],
    sqrt_iters: &mut Vec<u32>,
) {
    let (d, dff, dh, heads) = (geo.d, geo.d_ff, geo.dh(), geo.heads);
    let m = m_eff;
    assert!(
        m >= 1 && m <= s.geo.m && d == s.geo.d && dff == s.geo.d_ff && heads == s.geo.heads,
        "m_eff {m} / geometry incompatible with workspace built for {:?}",
        s.geo
    );
    assert_eq!(q_x.len(), m * d, "q_x shape");
    assert_eq!(q_out.len(), m * d, "q_out shape");

    let attn_parallel =
        s.attn_heads_parallel && heads > 1 && 2 * m * m * dh >= s.attn_par_min_macs;

    let LayerScratch {
        acc, q8, k8, v8, ctx8, x2, ln, scores, probs, row64,
        qh, kh, vh, ctx_h, res, g64, b64, hff, h8, ..
    } = s;
    let acc = &mut acc[..m * d];
    let q8 = &mut q8[..m * d];
    let k8 = &mut k8[..m * d];
    let v8 = &mut v8[..m * d];
    let ctx8 = &mut ctx8[..m * d];
    let x2 = &mut x2[..m * d];
    let ln = &mut ln[..m * d];
    let scores = &mut scores[..heads * m * m];
    let probs = &mut probs[..heads * m * m];
    let row64 = &mut row64[..heads * m];
    let qh = &mut qh[..heads * m * dh];
    let kh = &mut kh[..heads * m * dh];
    let vh = &mut vh[..heads * m * dh];
    let ctx_h = &mut ctx_h[..heads * m * dh];
    let res = &mut res[..m * d];
    let g64 = &mut g64[..d];
    let b64 = &mut b64[..d];
    let hff = &mut hff[..m * dff];
    let h8 = &mut h8[..m * dff];

    // --- Q/K/V projections on packed INT4 weights, requantization
    // fused at the readout through the 2^4-scaled dyadics ---
    let (dy_q, dy_k, dy_v) = (
        int4_readout_dyadic(c.dy_q),
        int4_readout_dyadic(c.dy_k),
        int4_readout_dyadic(c.dy_v),
    );
    i_matmul_int4_epilogue_par(q_x, &w.wq, Some(&w.bq), m, d, d, Epilogue::Requant(dy_q), q8);
    i_matmul_int4_epilogue_par(q_x, &w.wk, Some(&w.bk), m, d, d, Epilogue::Requant(dy_k), k8);
    i_matmul_int4_epilogue_par(q_x, &w.wv, Some(&w.bv), m, d, d, Epilogue::Requant(dy_v), v8);

    // --- Attention: identical INT8 activation-activation core ---
    if dh > 0 {
        let (q8, k8, v8) = (&*q8, &*k8, &*v8);
        let lanes = qh
            .chunks_mut(m * dh)
            .zip(kh.chunks_mut(m * dh))
            .zip(vh.chunks_mut(m * dh))
            .zip(ctx_h.chunks_mut(m * dh))
            .zip(scores.chunks_mut(m * m))
            .zip(probs.chunks_mut(m * m))
            .zip(row64.chunks_mut(m))
            .enumerate();
        if attn_parallel {
            let jobs: Vec<_> = lanes
                .map(|(h, ((((((qh, kh), vh), ctx_h), scores), probs), row64))| {
                    move || {
                        attention_head_fused(
                            h, m, d, dh, q8, k8, v8, c, false, qh, kh, vh, scores, probs,
                            row64, ctx_h,
                        )
                    }
                })
                .collect();
            run_scoped(jobs);
        } else {
            for (h, ((((((qh, kh), vh), ctx_h), scores), probs), row64)) in lanes {
                attention_head_fused(
                    h, m, d, dh, q8, k8, v8, c, true, qh, kh, vh, scores, probs, row64,
                    ctx_h,
                );
            }
        }
    }
    if heads * dh < d {
        for r in 0..m {
            ctx8[r * d + heads * dh..(r + 1) * d].fill(0);
        }
    }
    if dh > 0 {
        for (h, lane) in ctx_h.chunks(m * dh).enumerate() {
            for r in 0..m {
                ctx8[r * d + h * dh..r * d + (h + 1) * dh]
                    .copy_from_slice(&lane[r * dh..(r + 1) * dh]);
            }
        }
    }

    // --- output projection (INT4) with the scaled residual rescale
    // fused at readout, then the i64 residual add + LayerNorm 1 ---
    let dy_res1 = int4_readout_dyadic(c.dy_res1);
    i_matmul_int4_epilogue_par(ctx8, &w.wo, Some(&w.bo), m, d, d, Epilogue::Rescale(dy_res1), acc);
    for ((dst, &xv), &av) in res.iter_mut().zip(q_x).zip(acc.iter()) {
        *dst = xv as i64 + av as i64;
    }
    for (g, &v) in g64.iter_mut().zip(&w.gamma1) {
        *g = v as i64;
    }
    for (b, &v) in b64.iter_mut().zip(&w.beta1) {
        *b = v as i64;
    }
    for r in 0..m {
        let row = &mut ln[r * d..(r + 1) * d];
        let it = i_layernorm(&res[r * d..(r + 1) * d], g64, b64, &c.ln1, row);
        sqrt_iters.push(it);
    }
    requant_into(ln, c.dy_ln1, x2);

    // --- FFN: INT4 MatMul -> (<< INT4_SHIFT) -> GELU -> Req -> INT4
    // MatMul (scaled rescale fused).  The explicit shift restores the
    // INT8 accumulator scale *before* the non-linear polynomial — an
    // exact integer multiply, not an approximation ---
    i_matmul_int4_par(x2, &w.w1, Some(&w.b1), m, d, dff, hff);
    for (o, &v) in h8.iter_mut().zip(hff.iter()) {
        let acc8 = (v as i64) << INT4_SHIFT;
        *o = requantize_signed(quant::i_gelu(acc8, &c.gelu), c.dy_gelu, -1);
    }
    let dy_res2 = int4_readout_dyadic(c.dy_res2);
    i_matmul_int4_epilogue_par(h8, &w.w2, Some(&w.b2), m, dff, d, Epilogue::Rescale(dy_res2), acc);

    // --- residual align + LayerNorm 2 + output requant ---
    for ((dst, &xv), &av) in res.iter_mut().zip(x2.iter()).zip(acc.iter()) {
        *dst = xv as i64 + av as i64;
    }
    for (g, &v) in g64.iter_mut().zip(&w.gamma2) {
        *g = v as i64;
    }
    for (b, &v) in b64.iter_mut().zip(&w.beta2) {
        *b = v as i64;
    }
    for r in 0..m {
        let row = &mut ln[r * d..(r + 1) * d];
        let it = i_layernorm(&res[r * d..(r + 1) * d], g64, b64, &c.ln2, row);
        sqrt_iters.push(it);
    }
    requant_into(ln, c.dy_ln2, q_out);
}

/// The pre-fusion reference: serial head loop, separate full-tensor
/// requantization/rescale passes over a shared INT32 accumulator —
/// exactly the structure this file shipped before the fused path
/// (DESIGN.md §7).  Kept as the golden baseline the fused/parallel
/// path is asserted bit-exact against.
#[allow(clippy::too_many_arguments)]
fn layer_forward_scratch_unfused(
    q_x: &[i32],
    w: &LayerWeights,
    c: &LayerConsts,
    geo: &Geometry,
    m_eff: usize,
    s: &mut LayerScratch,
    q_out: &mut [i32],
    sqrt_iters: &mut Vec<u32>,
) {
    let (d, dff, dh, heads) = (geo.d, geo.d_ff, geo.dh(), geo.heads);
    let m = m_eff;
    assert!(
        m >= 1 && m <= s.geo.m && d == s.geo.d && dff == s.geo.d_ff && heads == s.geo.heads,
        "m_eff {m} / geometry incompatible with workspace built for {:?}",
        s.geo
    );
    assert_eq!(q_x.len(), m * d, "q_x shape");
    assert_eq!(q_out.len(), m * d, "q_out shape");

    let LayerScratch {
        acc, q8, k8, v8, ctx_acc, ctx8, x2, ln, scores, probs, row64,
        qh, kh, vh, ctx_h, res, g64, b64, hff, h8, ..
    } = s;
    let acc = &mut acc[..m * d];
    let q8 = &mut q8[..m * d];
    let k8 = &mut k8[..m * d];
    let v8 = &mut v8[..m * d];
    let ctx_acc = &mut ctx_acc[..m * d];
    let ctx8 = &mut ctx8[..m * d];
    let x2 = &mut x2[..m * d];
    let ln = &mut ln[..m * d];
    // lane 0 of the per-head buffers — this path reuses one head's lane
    let scores = &mut scores[..m * m];
    let probs = &mut probs[..m * m];
    let row64 = &mut row64[..m];
    let qh = &mut qh[..m * dh];
    let kh = &mut kh[..m * dh];
    let vh = &mut vh[..m * dh];
    let ctx_h = &mut ctx_h[..m * dh];
    let res = &mut res[..m * d];
    let g64 = &mut g64[..d];
    let b64 = &mut b64[..d];
    let hff = &mut hff[..m * dff];
    let h8 = &mut h8[..m * dff];

    // --- Q/K/V projections + Requantization ---
    i_matmul_par(q_x, &w.wq, Some(&w.bq), m, d, d, acc);
    requant_into(acc, c.dy_q, q8);
    i_matmul_par(q_x, &w.wk, Some(&w.bk), m, d, d, acc);
    requant_into(acc, c.dy_k, k8);
    i_matmul_par(q_x, &w.wv, Some(&w.bv), m, d, d, acc);
    requant_into(acc, c.dy_v, v8);

    // --- Attention per head: MatMul -> Scale -> Softmax -> Req -> MatMul ---
    // (heads * dh may undershoot d; the tail columns must stay zero, as
    // the freshly allocated accumulator of the wrapper path guarantees)
    ctx_acc.fill(0);
    for h in 0..heads {
        gather_head(q8, m, d, h, dh, qh);
        gather_head(k8, m, d, h, dh, kh);
        gather_head(v8, m, d, h, dh, vh);
        i_matmul_bt_par(qh, kh, m, dh, m, scores);
        // Scale block + Softmax rows
        for r in 0..m {
            for (dst, &sv) in row64.iter_mut().zip(&scores[r * m..(r + 1) * m]) {
                *dst = rescale(sv as i64, c.dy_scale);
            }
            i_softmax(row64, &c.softmax, &mut probs[r * m..(r + 1) * m]);
        }
        // P.V into the head's slice of the context accumulator
        i_matmul_par(probs, vh, None, m, m, dh, ctx_h);
        for r in 0..m {
            ctx_acc[r * d + h * dh..r * d + (h + 1) * dh]
                .copy_from_slice(&ctx_h[r * dh..(r + 1) * dh]);
        }
    }
    requant_into(ctx_acc, c.dy_ctx, ctx8);

    // --- output projection + residual align + LayerNorm 1 ---
    i_matmul_par(ctx8, &w.wo, Some(&w.bo), m, d, d, acc);
    for ((dst, &xv), &av) in res.iter_mut().zip(q_x).zip(acc.iter()) {
        *dst = xv as i64 + rescale(av as i64, c.dy_res1) as i32 as i64;
    }
    for (g, &v) in g64.iter_mut().zip(&w.gamma1) {
        *g = v as i64;
    }
    for (b, &v) in b64.iter_mut().zip(&w.beta1) {
        *b = v as i64;
    }
    for r in 0..m {
        let row = &mut ln[r * d..(r + 1) * d];
        let it = i_layernorm(&res[r * d..(r + 1) * d], g64, b64, &c.ln1, row);
        sqrt_iters.push(it);
    }
    requant_into(ln, c.dy_ln1, x2);

    // --- FFN: MatMul -> GELU -> Req -> MatMul ---
    i_matmul_par(x2, &w.w1, Some(&w.b1), m, d, dff, hff);
    for (o, &v) in h8.iter_mut().zip(hff.iter()) {
        *o = requantize_signed(quant::i_gelu(v as i64, &c.gelu), c.dy_gelu, -1);
    }
    i_matmul_par(h8, &w.w2, Some(&w.b2), m, dff, d, acc);

    // --- residual align + LayerNorm 2 + output requant ---
    for ((dst, &xv), &av) in res.iter_mut().zip(x2.iter()).zip(acc.iter()) {
        *dst = xv as i64 + rescale(av as i64, c.dy_res2) as i32 as i64;
    }
    for (g, &v) in g64.iter_mut().zip(&w.gamma2) {
        *g = v as i64;
    }
    for (b, &v) in b64.iter_mut().zip(&w.beta2) {
        *b = v as i64;
    }
    for r in 0..m {
        let row = &mut ln[r * d..(r + 1) * d];
        let it = i_layernorm(&res[r * d..(r + 1) * d], g64, b64, &c.ln2, row);
        sqrt_iters.push(it);
    }
    requant_into(ln, c.dy_ln2, q_out);
}

/// Workspace-based bit-exact encoder layer: runs `m_eff` live rows over
/// the resident arena, writing the INT8-coded output into `q_out`
/// (`m_eff * geo.d`) and appending `2 * m_eff` sqrt iteration counts
/// (ln1 rows then ln2 rows) to `sqrt_iters`.  Allocation-free once
/// `sqrt_iters` has capacity (DESIGN.md §6).  Runs the fused,
/// head-parallel attention core (DESIGN.md §7; knobs on [`Workspace`])
/// — bit-exact with [`layer_forward_ws_unfused`] for every input.
#[allow(clippy::too_many_arguments)]
pub fn layer_forward_ws(
    q_x: &[i32],
    w: &LayerWeights,
    c: &LayerConsts,
    geo: &Geometry,
    m_eff: usize,
    ws: &mut Workspace,
    q_out: &mut [i32],
    sqrt_iters: &mut Vec<u32>,
) {
    layer_forward_scratch(q_x, w, c, geo, m_eff, &mut ws.s, q_out, sqrt_iters);
}

/// Workspace-based INT4 encoder layer (DESIGN.md §14): the packed
/// low-precision twin of [`layer_forward_ws`], same arena, same
/// signature shape, `w` on the INT4 grid.  Reuses the identical
/// [`Workspace`] — the INT4 kernels write the same INT32 scratch
/// buffers, so an engine can hold either precision behind one arena.
#[allow(clippy::too_many_arguments)]
pub fn layer_forward_ws_int4(
    q_x: &[i32],
    w: &LayerWeightsInt4,
    c: &LayerConsts,
    geo: &Geometry,
    m_eff: usize,
    ws: &mut Workspace,
    q_out: &mut [i32],
    sqrt_iters: &mut Vec<u32>,
) {
    layer_forward_scratch_int4(q_x, w, c, geo, m_eff, &mut ws.s, q_out, sqrt_iters);
}

/// The serial, unfused reference layer over a caller-owned arena: the
/// pre-fusion structure (separate full-tensor requantization passes,
/// sequential head loop), same signature as [`layer_forward_ws`].  The
/// golden baseline of `rust/tests/attention_fused.rs` and the
/// comparison leg of the `serving_scaling` bench (EXPERIMENTS.md
/// §Perf).
#[allow(clippy::too_many_arguments)]
pub fn layer_forward_ws_unfused(
    q_x: &[i32],
    w: &LayerWeights,
    c: &LayerConsts,
    geo: &Geometry,
    m_eff: usize,
    ws: &mut Workspace,
    q_out: &mut [i32],
    sqrt_iters: &mut Vec<u32>,
) {
    layer_forward_scratch_unfused(q_x, w, c, geo, m_eff, &mut ws.s, q_out, sqrt_iters);
}

/// Bit-exact integer encoder layer (paper Figs. 5, 8-15): allocating
/// convenience wrapper at full length `geo.m`, running the serial
/// *unfused* reference structure — the pre-refactor function the golden
/// tests pin the fused path against; identical output by construction.
pub fn layer_forward(
    q_x: &[i32],
    w: &LayerWeights,
    c: &LayerConsts,
    geo: &Geometry,
) -> LayerOutput {
    let mut ws = Workspace::new(geo);
    let mut q_out = vec![0i32; geo.m * geo.d];
    let mut sqrt_iters = Vec::with_capacity(2 * geo.m);
    layer_forward_scratch_unfused(q_x, w, c, geo, geo.m, &mut ws.s, &mut q_out, &mut sqrt_iters);
    LayerOutput { q_out, sqrt_iters }
}

/// Workspace-based full encoder stack at live length `m_eff`: output
/// into `out` (`m_eff * geo.d`), `2 * m_eff` sqrt iteration counts per
/// layer appended to `sqrt_iters` (ln1 rows then ln2 rows, layer by
/// layer — the layout `sim::simulate_encoder_m` consumes).
#[allow(clippy::too_many_arguments)]
pub fn encoder_forward_ws(
    q_x: &[i32],
    layers: &[(LayerWeights, LayerConsts)],
    geo: &Geometry,
    m_eff: usize,
    ws: &mut Workspace,
    out: &mut [i32],
    sqrt_iters: &mut Vec<u32>,
) {
    let n = m_eff * geo.d;
    assert_eq!(q_x.len(), n, "q_x shape");
    assert_eq!(out.len(), n, "out shape");
    let Workspace { s, act0, act1 } = ws;
    if layers.is_empty() {
        out.copy_from_slice(q_x);
        return;
    }
    let mut cur: &mut [i32] = &mut act0[..n];
    let mut nxt: &mut [i32] = &mut act1[..n];
    let (w0, c0) = &layers[0];
    layer_forward_scratch(q_x, w0, c0, geo, m_eff, s, cur, sqrt_iters);
    for (w, c) in &layers[1..] {
        layer_forward_scratch(cur, w, c, geo, m_eff, s, nxt, sqrt_iters);
        std::mem::swap(&mut cur, &mut nxt);
    }
    out.copy_from_slice(cur);
}

/// Workspace-based INT4 full encoder stack: the packed low-precision
/// twin of [`encoder_forward_ws`], same output/`sqrt_iters` contract
/// (the cycle simulator consumes the identical layout, so an INT4
/// replica's data-dependent timing path keeps working).
#[allow(clippy::too_many_arguments)]
pub fn encoder_forward_ws_int4(
    q_x: &[i32],
    layers: &[(LayerWeightsInt4, LayerConsts)],
    geo: &Geometry,
    m_eff: usize,
    ws: &mut Workspace,
    out: &mut [i32],
    sqrt_iters: &mut Vec<u32>,
) {
    let n = m_eff * geo.d;
    assert_eq!(q_x.len(), n, "q_x shape");
    assert_eq!(out.len(), n, "out shape");
    let Workspace { s, act0, act1 } = ws;
    if layers.is_empty() {
        out.copy_from_slice(q_x);
        return;
    }
    let mut cur: &mut [i32] = &mut act0[..n];
    let mut nxt: &mut [i32] = &mut act1[..n];
    let (w0, c0) = &layers[0];
    layer_forward_scratch_int4(q_x, w0, c0, geo, m_eff, s, cur, sqrt_iters);
    for (w, c) in &layers[1..] {
        layer_forward_scratch_int4(cur, w, c, geo, m_eff, s, nxt, sqrt_iters);
        std::mem::swap(&mut cur, &mut nxt);
    }
    out.copy_from_slice(cur);
}

/// Full integer encoder stack: allocating convenience wrapper over
/// [`encoder_forward_ws`] at full length `geo.m`.
pub fn encoder_forward(
    q_x: &[i32],
    layers: &[(LayerWeights, LayerConsts)],
    geo: &Geometry,
) -> (Vec<i32>, Vec<u32>) {
    let mut ws = Workspace::new(geo);
    let mut out = vec![0i32; geo.m * geo.d];
    let mut iters = Vec::with_capacity(2 * geo.m * layers.len());
    encoder_forward_ws(q_x, layers, geo, geo.m, &mut ws, &mut out, &mut iters);
    (out, iters)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_geo() -> Geometry {
        Geometry::new(16, 2, 8, 32, 1)
    }

    fn rand_w(rng: &mut Rng, n: usize, lim: i64) -> Vec<i32> {
        (0..n).map(|_| rng.range_i64(-lim, lim) as i32).collect()
    }

    fn consts(geo: &Geometry) -> LayerConsts {
        synthetic_consts(geo)
    }

    fn weights(rng: &mut Rng, geo: &Geometry) -> LayerWeights {
        LayerWeights::synthetic(rng, geo)
    }

    #[test]
    fn output_is_int8_coded() {
        let geo = tiny_geo();
        let mut rng = Rng::new(3);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        let x = rand_w(&mut rng, geo.m * geo.d, 127);
        let out = layer_forward(&x, &w, &c, &geo);
        assert!(out.q_out.iter().all(|&v| (-128..=127).contains(&v)));
        assert_eq!(out.sqrt_iters.len(), 2 * geo.m);
    }

    #[test]
    fn deterministic() {
        let geo = tiny_geo();
        let mut rng = Rng::new(3);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        let x = rand_w(&mut rng, geo.m * geo.d, 127);
        let a = layer_forward(&x, &w, &c, &geo).q_out;
        let b = layer_forward(&x, &w, &c, &geo).q_out;
        assert_eq!(a, b);
    }

    #[test]
    fn input_sensitivity() {
        let geo = tiny_geo();
        let mut rng = Rng::new(4);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        let x = rand_w(&mut rng, geo.m * geo.d, 127);
        let mut x2 = x.clone();
        for v in x2.iter_mut().take(geo.d) {
            *v = (*v + 40).min(127);
        }
        let a = layer_forward(&x, &w, &c, &geo).q_out;
        let b = layer_forward(&x2, &w, &c, &geo).q_out;
        assert_ne!(a, b);
    }

    #[test]
    fn encoder_stacks_layers() {
        let geo = Geometry::new(16, 2, 8, 32, 2);
        let mut rng = Rng::new(5);
        let layers: Vec<_> = (0..2)
            .map(|_| (weights(&mut rng, &geo), consts(&geo)))
            .collect();
        let x = rand_w(&mut rng, geo.m * geo.d, 127);
        let (out, iters) = encoder_forward(&x, &layers, &geo);
        assert_eq!(out.len(), geo.m * geo.d);
        assert_eq!(iters.len(), 2 * 2 * geo.m);
    }

    #[test]
    fn workspace_reuse_matches_fresh_workspace() {
        // A warm arena must not leak state between requests: running the
        // same inputs through one reused workspace, interleaved with
        // other shapes, stays bit-identical to fresh evaluations.
        let geo = tiny_geo();
        let mut rng = Rng::new(6);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        let x_full = rand_w(&mut rng, geo.m * geo.d, 127);
        let x_short = rand_w(&mut rng, 3 * geo.d, 127);

        let mut ws = Workspace::new(&geo);
        let mut out = vec![0i32; geo.m * geo.d];
        let mut iters = Vec::new();
        for _ in 0..3 {
            iters.clear();
            layer_forward_ws(&x_full, &w, &c, &geo, geo.m, &mut ws, &mut out, &mut iters);
            let fresh = layer_forward(&x_full, &w, &c, &geo);
            assert_eq!(out, fresh.q_out);
            assert_eq!(iters, fresh.sqrt_iters);
            // pollute the arena with a different live length
            iters.clear();
            layer_forward_ws(&x_short, &w, &c, &geo, 3, &mut ws, &mut out[..3 * geo.d], &mut iters);
        }
    }

    #[test]
    fn short_m_eff_matches_truncated_geometry() {
        // m_eff < geo.m on the big arena == full-length run on a
        // geometry truncated to m = m_eff (weights are m-independent).
        let geo = tiny_geo();
        let mut rng = Rng::new(7);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        for m_eff in [1usize, 3, 5, geo.m] {
            let x = rand_w(&mut rng, m_eff * geo.d, 127);
            let mut ws = Workspace::new(&geo);
            let mut out = vec![0i32; m_eff * geo.d];
            let mut iters = Vec::new();
            layer_forward_ws(&x, &w, &c, &geo, m_eff, &mut ws, &mut out, &mut iters);

            let trunc = Geometry { m: m_eff, ..geo };
            let want = layer_forward(&x, &w, &c, &trunc);
            assert_eq!(out, want.q_out, "m_eff={m_eff}");
            assert_eq!(iters, want.sqrt_iters, "m_eff={m_eff}");
        }
    }

    #[test]
    fn fused_head_parallel_matches_unfused_reference() {
        // All four execution modes — fused parallel (forced), fused
        // serial, unfused over the arena, unfused allocating wrapper —
        // must agree bit for bit, outputs and sqrt iteration counts.
        let geo = tiny_geo();
        let mut rng = Rng::new(9);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        for m_eff in [1usize, 3, geo.m] {
            let x = rand_w(&mut rng, m_eff * geo.d, 127);

            let mut ws = Workspace::new(&geo);
            ws.set_attn_par_min_macs(0); // force the scoped parallel-for
            let mut out_par = vec![0i32; m_eff * geo.d];
            let mut it_par = Vec::new();
            layer_forward_ws(&x, &w, &c, &geo, m_eff, &mut ws, &mut out_par, &mut it_par);

            let mut ws2 = Workspace::new(&geo);
            ws2.set_attn_heads_parallel(false);
            let mut out_ser = vec![0i32; m_eff * geo.d];
            let mut it_ser = Vec::new();
            layer_forward_ws(&x, &w, &c, &geo, m_eff, &mut ws2, &mut out_ser, &mut it_ser);

            let mut ws3 = Workspace::new(&geo);
            let mut out_ref = vec![0i32; m_eff * geo.d];
            let mut it_ref = Vec::new();
            layer_forward_ws_unfused(&x, &w, &c, &geo, m_eff, &mut ws3, &mut out_ref, &mut it_ref);

            assert_eq!(out_par, out_ref, "parallel fused vs unfused, m_eff={m_eff}");
            assert_eq!(it_par, it_ref, "sqrt iters, m_eff={m_eff}");
            assert_eq!(out_ser, out_ref, "serial fused vs unfused, m_eff={m_eff}");
            assert_eq!(it_ser, it_ref, "sqrt iters serial, m_eff={m_eff}");

            let trunc = Geometry { m: m_eff, ..geo };
            let want = layer_forward(&x, &w, &c, &trunc);
            assert_eq!(out_par, want.q_out, "wrapper agreement, m_eff={m_eff}");
        }
    }

    /// Snap every weight onto the INT4 grid (multiples of 16) so the
    /// quantization step is lossless; biases snap to the matching
    /// 16-multiple grid the same way.
    fn snap_to_int4_grid(w: &LayerWeights) -> LayerWeights {
        let snap = |v: &[i32]| -> Vec<i32> {
            v.iter()
                .map(|&x| 16 * crate::quant::div_floor(x as i64 + 8, 16).clamp(-8, 7) as i32)
                .collect()
        };
        LayerWeights {
            wq: snap(&w.wq),
            bq: snap(&w.bq),
            wk: snap(&w.wk),
            bk: snap(&w.bk),
            wv: snap(&w.wv),
            bv: snap(&w.bv),
            wo: snap(&w.wo),
            bo: snap(&w.bo),
            w1: snap(&w.w1),
            b1: snap(&w.b1),
            w2: snap(&w.w2),
            b2: snap(&w.b2),
            gamma1: w.gamma1.clone(),
            beta1: w.beta1.clone(),
            gamma2: w.gamma2.clone(),
            beta2: w.beta2.clone(),
        }
    }

    #[test]
    fn int4_path_bit_identical_to_int8_on_grid_aligned_weights() {
        // On weights that sit exactly on the INT4 grid the quantizer is
        // lossless and every accumulator is exactly 1/16 of its INT8
        // twin — so the scale_pow2 epilogues and the pre-GELU shift
        // must reproduce the INT8 layer *bit for bit*.  This pins the
        // whole compensation chain (qkv requant, res1/res2 rescales,
        // GELU shift) at once; any off-by-one in the floor-rounding
        // conventions breaks it.
        let geo = tiny_geo();
        let mut rng = Rng::new(21);
        let w8 = snap_to_int4_grid(&weights(&mut rng, &geo));
        let w4 = LayerWeightsInt4::quantize(&w8, &geo);
        let c = consts(&geo);
        for m_eff in [1usize, 3, geo.m] {
            let x = rand_w(&mut rng, m_eff * geo.d, 127);
            let mut ws8 = Workspace::new(&geo);
            let mut out8 = vec![0i32; m_eff * geo.d];
            let mut it8 = Vec::new();
            layer_forward_ws(&x, &w8, &c, &geo, m_eff, &mut ws8, &mut out8, &mut it8);

            let mut ws4 = Workspace::new(&geo);
            let mut out4 = vec![0i32; m_eff * geo.d];
            let mut it4 = Vec::new();
            layer_forward_ws_int4(&x, &w4, &c, &geo, m_eff, &mut ws4, &mut out4, &mut it4);

            assert_eq!(out4, out8, "grid-aligned int4 vs int8, m_eff={m_eff}");
            assert_eq!(it4, it8, "sqrt iters, m_eff={m_eff}");
        }
    }

    #[test]
    fn int4_serial_and_parallel_paths_agree() {
        // The INT4 layer's head-parallel and serial execution modes are
        // the same numerics (the attention core is shared with INT8);
        // forcing the scoped parallel-for must change nothing.
        let geo = tiny_geo();
        let mut rng = Rng::new(22);
        let w8 = weights(&mut rng, &geo);
        let w4 = LayerWeightsInt4::quantize(&w8, &geo);
        let c = consts(&geo);
        let x = rand_w(&mut rng, geo.m * geo.d, 127);

        let mut ws_par = Workspace::new(&geo);
        ws_par.set_attn_par_min_macs(0);
        let mut out_par = vec![0i32; geo.m * geo.d];
        let mut it_par = Vec::new();
        layer_forward_ws_int4(&x, &w4, &c, &geo, geo.m, &mut ws_par, &mut out_par, &mut it_par);

        let mut ws_ser = Workspace::new(&geo);
        ws_ser.set_attn_heads_parallel(false);
        let mut out_ser = vec![0i32; geo.m * geo.d];
        let mut it_ser = Vec::new();
        layer_forward_ws_int4(&x, &w4, &c, &geo, geo.m, &mut ws_ser, &mut out_ser, &mut it_ser);

        assert_eq!(out_par, out_ser);
        assert_eq!(it_par, it_ser);
        assert!(out_par.iter().all(|&v| (-128..=127).contains(&v)), "INT8-coded output");
    }

    #[test]
    fn int4_encoder_stacks_and_tracks_int8_loosely() {
        // Off-grid weights make INT4 an approximation: the output must
        // still be INT8-coded, deterministic, and *correlated* with the
        // INT8 stack (identical signs on a large majority of entries) —
        // close enough for the cascade's front tier to be useful.
        let geo = Geometry::new(16, 2, 8, 32, 2);
        let mut rng = Rng::new(23);
        let layers8: Vec<_> =
            (0..2).map(|_| (weights(&mut rng, &geo), consts(&geo))).collect();
        let layers4: Vec<_> = layers8
            .iter()
            .map(|(w, c)| (LayerWeightsInt4::quantize(w, &geo), c.clone()))
            .collect();
        let x = rand_w(&mut rng, geo.m * geo.d, 127);

        let mut ws = Workspace::new(&geo);
        let mut out4 = vec![0i32; geo.m * geo.d];
        let mut it4 = Vec::new();
        encoder_forward_ws_int4(&x, &layers4, &geo, geo.m, &mut ws, &mut out4, &mut it4);
        assert_eq!(it4.len(), 2 * 2 * geo.m);
        assert!(out4.iter().all(|&v| (-128..=127).contains(&v)));

        let mut out4b = vec![0i32; geo.m * geo.d];
        let mut it4b = Vec::new();
        encoder_forward_ws_int4(&x, &layers4, &geo, geo.m, &mut ws, &mut out4b, &mut it4b);
        assert_eq!(out4, out4b, "deterministic");

        let (out8, _) = encoder_forward(&x, &layers8, &geo);
        let agree = out4
            .iter()
            .zip(&out8)
            .filter(|(&a, &b)| (a >= 0) == (b >= 0))
            .count();
        assert!(
            agree * 2 > out8.len(),
            "int4 output decorrelated from int8: {agree}/{} sign agreement",
            out8.len()
        );
    }

    #[test]
    fn encoder_ws_matches_wrapper_and_truncated_geometry() {
        let geo = Geometry::new(16, 2, 8, 32, 2);
        let mut rng = Rng::new(8);
        let layers: Vec<_> = (0..2)
            .map(|_| (weights(&mut rng, &geo), consts(&geo)))
            .collect();
        let x = rand_w(&mut rng, geo.m * geo.d, 127);

        let mut ws = Workspace::new(&geo);
        let mut out = vec![0i32; geo.m * geo.d];
        let mut iters = Vec::new();
        encoder_forward_ws(&x, &layers, &geo, geo.m, &mut ws, &mut out, &mut iters);
        let (want_out, want_iters) = encoder_forward(&x, &layers, &geo);
        assert_eq!(out, want_out);
        assert_eq!(iters, want_iters);

        // short request over the same (warm) arena
        let m_eff = 5;
        let xs = rand_w(&mut rng, m_eff * geo.d, 127);
        let mut out_s = vec![0i32; m_eff * geo.d];
        iters.clear();
        encoder_forward_ws(&xs, &layers, &geo, m_eff, &mut ws, &mut out_s, &mut iters);
        let trunc = Geometry { m: m_eff, ..geo };
        let (want_s, want_iters_s) = {
            let mut ws2 = Workspace::new(&trunc);
            let mut o = vec![0i32; m_eff * trunc.d];
            let mut it = Vec::new();
            encoder_forward_ws(&xs, &layers, &trunc, m_eff, &mut ws2, &mut o, &mut it);
            (o, it)
        };
        assert_eq!(out_s, want_s);
        assert_eq!(iters, want_iters_s);
        assert_eq!(iters.len(), 2 * 2 * m_eff);
    }
}
