//! Model registry: the multi-tenant front door of the serving stack
//! (DESIGN.md §8).
//!
//! A [`ModelRegistry`] maps *model ids* (tenant-facing names) to
//! [`Geometry`] presets and owns one replica group per model — a set of
//! identical [`FunctionalEngine`] replicas sharing a single
//! [`SyntheticModel`](super::engine::SyntheticModel) weight bundle,
//! each replica sized by its own [`HwConfig::sized_to`] hardware
//! instance (the paper's §III-D design-time tunables: array rows = m,
//! columns = d, one head unit per model head).  The finished registry
//! converts into the [`ModelGroup`] list that
//! [`Router::start_multi`](super::Router::start_multi) serves, with
//! each group's fair-share `weight` feeding the batcher's deficit
//! round-robin dispatcher.
//!
//! PJRT-backed [`InferenceEngine`](super::InferenceEngine) replicas
//! stay single-model (one AOT artifact per process); heterogeneous
//! custom backends can still join a registry through
//! [`ModelRegistry::register_group`].

use super::engine::{EngineReplica, FunctionalEngine};
use crate::model::Geometry;
use crate::sim::{CostModel, HwConfig};
use std::sync::Arc;

/// Builds one more identical replica of a model on demand — what the
/// autoscaler invokes to grow a group (a `FunctionalEngine` spawner
/// captures the shared `Arc<SyntheticModel>` weight bundle, so a grow
/// costs one Workspace arena, never a weight copy; DESIGN.md §9).
pub type ReplicaFactory = Arc<dyn Fn() -> Result<Arc<dyn EngineReplica>, String> + Send + Sync>;

/// Default cascade escalation threshold on the top-1 logit margin
/// `|logits[0] - logits[1]|`, tuned on the synthetic workload
/// (EXPERIMENTS.md §Cascade): at roberta_base width the INT4 tier's
/// label flips against INT8 concentrate below this margin, so
/// escalating the ~2-3% of requests under it recovers ≥ 99% top-1
/// agreement while keeping the escalation surcharge small.
pub const DEFAULT_ESCALATE_MARGIN: i64 = 6000;

/// One model's serving group, ready for the router: the tenant-facing
/// name, its (identical) initial replicas, its fair-share weight, the
/// `min..=max` replica range the autoscaler may move within, the
/// latency SLO the backlog is judged against, and the factory that
/// spawns additional replicas (absent => the group is fixed-size).
pub struct ModelGroup {
    pub model: String,
    pub replicas: Vec<Arc<dyn EngineReplica>>,
    /// Fair-share weight: the group's share of every DRR-arbitrated
    /// resource — the batcher shard ledger at pop time *and* the
    /// router's global core-budget workers (DESIGN.md §8, §13).
    pub weight: u64,
    /// Fewest replicas the autoscaler may drain the group down to.
    pub min_replicas: usize,
    /// Most replicas the autoscaler may grow the group to (also the
    /// group's reserved global-replica-id span and its contribution
    /// to the default core budget — Σ group widths; DESIGN.md §13).
    pub max_replicas: usize,
    /// Target end-to-end latency class in milliseconds; `None` opts the
    /// group out of autoscaling.
    pub slo_ms: Option<f64>,
    pub factory: Option<ReplicaFactory>,
    /// Closed-form cost model of this group's `(geometry, hardware)`
    /// pair, built once at registration and shared with every replica
    /// (DESIGN.md §12).  The router charges batcher fairness and the
    /// autoscaler/admission paths score backlog through it; custom
    /// groups without a geometry (`None`) fall back to token-charged
    /// accounting.
    pub cost: Option<Arc<CostModel>>,
    /// Cascade link (DESIGN.md §14): the model id low-margin responses
    /// from this group escalate to.  `Some` marks this group as a
    /// front tier (typically INT4); the named group must exist in the
    /// same registry and serve the same tokens (same shared
    /// `SyntheticModel`).
    pub escalate_to: Option<String>,
    /// Top-1 logit-margin threshold below which a front-tier response
    /// escalates instead of being served (ignored when `escalate_to`
    /// is `None`).  Per-tenant overrides ride on the request.
    pub escalate_margin: i64,
}

impl ModelGroup {
    /// A fixed-size group: `min == max == replicas.len()`, no SLO, no
    /// factory — the pre-autoscaler shape (DESIGN.md §8).
    pub fn fixed(
        model: impl Into<String>,
        replicas: Vec<Arc<dyn EngineReplica>>,
        weight: u64,
    ) -> ModelGroup {
        let n = replicas.len();
        ModelGroup {
            model: model.into(),
            replicas,
            weight,
            min_replicas: n,
            max_replicas: n,
            slo_ms: None,
            factory: None,
            cost: None,
            escalate_to: None,
            escalate_margin: 0,
        }
    }

    /// Whether the autoscaler has any room to act on this group —
    /// must match the runtime-side gate
    /// (`coordinator::pool::GroupRuntime::scalable`): a range, a
    /// factory, AND an SLO class (a group without a latency target is
    /// never scaled, whatever its backlog).
    pub fn scalable(&self) -> bool {
        self.max_replicas > self.min_replicas
            && self.factory.is_some()
            && self.slo_ms.is_some()
    }
}

struct Entry {
    name: String,
    preset: Option<String>,
    geometry: Option<Geometry>,
    weight: u64,
    replicas: Vec<Arc<dyn EngineReplica>>,
    min_replicas: usize,
    max_replicas: usize,
    slo_ms: Option<f64>,
    factory: Option<ReplicaFactory>,
    cost: Option<Arc<CostModel>>,
    escalate_to: Option<String>,
    escalate_margin: i64,
}

/// Registry of resident models, built once at startup and converted
/// into router groups.  Model ids are unique; registration order is the
/// model-index order used by the batcher and metrics ledgers.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<Entry>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    fn check(&self, name: &str, replicas: usize, weight: u64) -> Result<(), String> {
        if name.is_empty() {
            return Err("model id must be non-empty".into());
        }
        if self.entries.iter().any(|e| e.name == name) {
            return Err(format!("model {name:?} already registered"));
        }
        if replicas == 0 {
            return Err(format!("model {name:?} needs at least one replica"));
        }
        if weight == 0 {
            return Err(format!("model {name:?} needs a positive fair-share weight"));
        }
        Ok(())
    }

    /// Register `replicas` identical synthetic replicas of a geometry
    /// preset under `name`, with fair-share `weight`.  The hardware
    /// instance is sized to the preset ([`HwConfig::sized_to`]); the
    /// weight bundle is generated once from `seed` and shared across
    /// the group's replicas.  The group is fixed-size (`min == max ==
    /// replicas`, no SLO); use
    /// [`register_scaled`](ModelRegistry::register_scaled) for an
    /// autoscaled range.
    pub fn register(
        &mut self,
        name: &str,
        preset: &str,
        replicas: usize,
        weight: u64,
        seed: u64,
    ) -> Result<&mut Self, String> {
        self.register_scaled(name, preset, replicas, replicas, weight, None, seed)
    }

    /// Register an autoscaled synthetic group: it starts at
    /// `min_replicas` and the router's autoscaler moves it within
    /// `min_replicas..=max_replicas` as the backlog-vs-SLO ratio
    /// demands (`slo_ms` is the model's target end-to-end latency
    /// class; `None` keeps the group at `min_replicas` even when `max`
    /// is larger).  Every spawned replica shares the one
    /// `SyntheticModel` bundle built here.
    #[allow(clippy::too_many_arguments)]
    pub fn register_scaled(
        &mut self,
        name: &str,
        preset: &str,
        min_replicas: usize,
        max_replicas: usize,
        weight: u64,
        slo_ms: Option<f64>,
        seed: u64,
    ) -> Result<&mut Self, String> {
        let geo = Geometry::preset(preset).ok_or_else(|| {
            format!("unknown preset {preset:?} (expected one of {:?})", Geometry::PRESET_NAMES)
        })?;
        self.register_scaled_with_hw(
            name,
            preset,
            min_replicas,
            max_replicas,
            weight,
            slo_ms,
            seed,
            HwConfig::sized_to(&geo),
        )
    }

    /// [`register`](ModelRegistry::register) with an explicit hardware
    /// configuration (benchmarks and tests pin the instance).
    pub fn register_with_hw(
        &mut self,
        name: &str,
        preset: &str,
        replicas: usize,
        weight: u64,
        seed: u64,
        hw: HwConfig,
    ) -> Result<&mut Self, String> {
        self.register_scaled_with_hw(name, preset, replicas, replicas, weight, None, seed, hw)
    }

    /// [`register_scaled`](ModelRegistry::register_scaled) with an
    /// explicit hardware configuration.
    #[allow(clippy::too_many_arguments)]
    pub fn register_scaled_with_hw(
        &mut self,
        name: &str,
        preset: &str,
        min_replicas: usize,
        max_replicas: usize,
        weight: u64,
        slo_ms: Option<f64>,
        seed: u64,
        hw: HwConfig,
    ) -> Result<&mut Self, String> {
        self.check(name, min_replicas, weight)?;
        check_range(name, min_replicas, max_replicas, slo_ms)?;
        let geo = Geometry::preset(preset).ok_or_else(|| {
            format!("unknown preset {preset:?} (expected one of {:?})", Geometry::PRESET_NAMES)
        })?;
        hw.validate(&geo)?;
        // one CostModel build per group: every initial replica, every
        // factory-spawned replica, and the router's scheduling paths
        // all share it (DESIGN.md §12)
        let cost = Arc::new(CostModel::build(&hw, &geo)?);
        let model = Arc::new(super::engine::SyntheticModel::build(preset, seed)?);
        let replicas: Vec<Arc<dyn EngineReplica>> = (0..min_replicas)
            .map(|_| {
                Arc::new(FunctionalEngine::from_model_with_cost(
                    Arc::clone(&model),
                    hw,
                    Arc::clone(&cost),
                )) as Arc<dyn EngineReplica>
            })
            .collect();
        let factory_cost = Arc::clone(&cost);
        let factory: ReplicaFactory = Arc::new(move || {
            Ok(Arc::new(FunctionalEngine::from_model_with_cost(
                Arc::clone(&model),
                hw,
                Arc::clone(&factory_cost),
            )) as Arc<dyn EngineReplica>)
        });
        self.entries.push(Entry {
            name: name.to_string(),
            preset: Some(preset.to_string()),
            geometry: Some(geo),
            weight,
            replicas,
            min_replicas,
            max_replicas,
            slo_ms,
            factory: Some(factory),
            cost: Some(cost),
            escalate_to: None,
            escalate_margin: 0,
        });
        Ok(self)
    }

    /// Register a *cascade pair* (DESIGN.md §14): an INT4 front tier
    /// under `name` plus its INT8 escalation sibling under
    /// `"{name}@int8"`, both derived from the **same** synthetic weight
    /// bundle built once from `seed`.  The INT4 tier quantizes that
    /// bundle onto the packed-nibble grid once
    /// ([`SyntheticModel::quantize_int4`](super::engine::SyntheticModel::quantize_int4))
    /// and shares the lanes across its replicas; its hardware instance
    /// is the equal-silicon [`HwConfig::int4_variant`] of the sibling's,
    /// so the pair's [`CostModel`]s price the same die at two
    /// precisions.  Requests dispatch to the front tier; responses
    /// whose top-1 logit margin falls below `escalate_margin` re-enter
    /// the router bound for the sibling instead of being served.  Both
    /// groups autoscale independently within `min..=max`.
    #[allow(clippy::too_many_arguments)]
    pub fn register_cascade_scaled(
        &mut self,
        name: &str,
        preset: &str,
        min_replicas: usize,
        max_replicas: usize,
        weight: u64,
        slo_ms: Option<f64>,
        seed: u64,
        escalate_margin: i64,
    ) -> Result<&mut Self, String> {
        let geo = Geometry::preset(preset).ok_or_else(|| {
            format!("unknown preset {preset:?} (expected one of {:?})", Geometry::PRESET_NAMES)
        })?;
        self.register_cascade_scaled_with_hw(
            name,
            preset,
            min_replicas,
            max_replicas,
            weight,
            slo_ms,
            seed,
            HwConfig::sized_to(&geo),
            escalate_margin,
        )
    }

    /// [`register_cascade_scaled`](ModelRegistry::register_cascade_scaled)
    /// with an explicit INT8-tier hardware configuration (the INT4 tier
    /// always runs its [`HwConfig::int4_variant`]).
    #[allow(clippy::too_many_arguments)]
    pub fn register_cascade_scaled_with_hw(
        &mut self,
        name: &str,
        preset: &str,
        min_replicas: usize,
        max_replicas: usize,
        weight: u64,
        slo_ms: Option<f64>,
        seed: u64,
        hw8: HwConfig,
        escalate_margin: i64,
    ) -> Result<&mut Self, String> {
        let sibling = format!("{name}@int8");
        self.check(name, min_replicas, weight)?;
        self.check(&sibling, min_replicas, weight)?;
        check_range(name, min_replicas, max_replicas, slo_ms)?;
        if escalate_margin < 0 {
            return Err(format!(
                "model {name:?}: escalation margin must be non-negative, got {escalate_margin}"
            ));
        }
        let geo = Geometry::preset(preset).ok_or_else(|| {
            format!("unknown preset {preset:?} (expected one of {:?})", Geometry::PRESET_NAMES)
        })?;
        hw8.validate(&geo)?;
        let hw4 = hw8.int4_variant();
        hw4.validate(&geo)?;
        let cost8 = Arc::new(CostModel::build(&hw8, &geo)?);
        let cost4 = Arc::new(CostModel::build(&hw4, &geo)?);
        let model = Arc::new(super::engine::SyntheticModel::build(preset, seed)?);
        // quantize the shared bundle onto the nibble grid exactly once;
        // every INT4 replica (initial and factory-spawned) borrows it
        let lanes4 = Arc::new(model.quantize_int4());

        let int4_replicas: Vec<Arc<dyn EngineReplica>> = (0..min_replicas)
            .map(|_| {
                Arc::new(FunctionalEngine::from_model_int4(
                    Arc::clone(&model),
                    Arc::clone(&lanes4),
                    hw4,
                    Arc::clone(&cost4),
                )) as Arc<dyn EngineReplica>
            })
            .collect();
        let f_model = Arc::clone(&model);
        let f_lanes = Arc::clone(&lanes4);
        let f_cost = Arc::clone(&cost4);
        let int4_factory: ReplicaFactory = Arc::new(move || {
            Ok(Arc::new(FunctionalEngine::from_model_int4(
                Arc::clone(&f_model),
                Arc::clone(&f_lanes),
                hw4,
                Arc::clone(&f_cost),
            )) as Arc<dyn EngineReplica>)
        });
        self.entries.push(Entry {
            name: name.to_string(),
            preset: Some(preset.to_string()),
            geometry: Some(geo),
            weight,
            replicas: int4_replicas,
            min_replicas,
            max_replicas,
            slo_ms,
            factory: Some(int4_factory),
            cost: Some(cost4),
            escalate_to: Some(sibling.clone()),
            escalate_margin,
        });

        let int8_replicas: Vec<Arc<dyn EngineReplica>> = (0..min_replicas)
            .map(|_| {
                Arc::new(FunctionalEngine::from_model_with_cost(
                    Arc::clone(&model),
                    hw8,
                    Arc::clone(&cost8),
                )) as Arc<dyn EngineReplica>
            })
            .collect();
        let f_cost8 = Arc::clone(&cost8);
        let int8_factory: ReplicaFactory = Arc::new(move || {
            Ok(Arc::new(FunctionalEngine::from_model_with_cost(
                Arc::clone(&model),
                hw8,
                Arc::clone(&f_cost8),
            )) as Arc<dyn EngineReplica>)
        });
        self.entries.push(Entry {
            name: sibling,
            preset: Some(preset.to_string()),
            geometry: Some(geo),
            weight,
            replicas: int8_replicas,
            min_replicas,
            max_replicas,
            slo_ms,
            factory: Some(int8_factory),
            cost: Some(cost8),
            escalate_to: None,
            escalate_margin: 0,
        });
        Ok(self)
    }

    /// Register a custom replica group (mock engines, or a single-model
    /// PJRT group).  All replicas must serve the same model; the
    /// registry has no preset geometry for such a group, and without a
    /// factory it stays fixed-size.
    pub fn register_group(
        &mut self,
        name: &str,
        replicas: Vec<Arc<dyn EngineReplica>>,
        weight: u64,
    ) -> Result<&mut Self, String> {
        self.check(name, replicas.len(), weight)?;
        let n = replicas.len();
        self.entries.push(Entry {
            name: name.to_string(),
            preset: None,
            geometry: None,
            weight,
            replicas,
            min_replicas: n,
            max_replicas: n,
            slo_ms: None,
            factory: None,
            cost: None,
            escalate_to: None,
            escalate_margin: 0,
        });
        Ok(self)
    }

    /// Register an autoscaled custom group: `min_replicas` instances
    /// are built from `factory` up front and the autoscaler may grow
    /// the group to `max_replicas` under backlog (tests use this with
    /// deterministic mock engines).
    pub fn register_group_scaled(
        &mut self,
        name: &str,
        min_replicas: usize,
        max_replicas: usize,
        weight: u64,
        slo_ms: Option<f64>,
        factory: ReplicaFactory,
    ) -> Result<&mut Self, String> {
        self.check(name, min_replicas, weight)?;
        check_range(name, min_replicas, max_replicas, slo_ms)?;
        let replicas: Vec<Arc<dyn EngineReplica>> =
            (0..min_replicas).map(|_| factory()).collect::<Result<_, _>>()?;
        self.entries.push(Entry {
            name: name.to_string(),
            preset: None,
            geometry: None,
            weight,
            replicas,
            min_replicas,
            max_replicas,
            slo_ms,
            factory: Some(factory),
            cost: None,
            escalate_to: None,
            escalate_margin: 0,
        });
        Ok(self)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered model ids, deterministically ordered (sorted) — a
    /// stable listing for operator surfaces and tests regardless of
    /// registration order.  The *model-index* order (batcher shards,
    /// metrics ledgers) remains registration order; consult
    /// [`into_groups`](ModelRegistry::into_groups) for that.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.iter().map(|e| e.name.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Geometry preset backing `name` (None for custom groups or
    /// unknown ids).
    pub fn geometry(&self, name: &str) -> Option<Geometry> {
        self.entries.iter().find(|e| e.name == name).and_then(|e| e.geometry)
    }

    /// Preset name backing `name`.
    pub fn preset(&self, name: &str) -> Option<&str> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .and_then(|e| e.preset.as_deref())
    }

    /// Fair-share weight of `name`.
    pub fn weight(&self, name: &str) -> Option<u64> {
        self.entries.iter().find(|e| e.name == name).map(|e| e.weight)
    }

    /// Longest request `name`'s group can serve (the intersection of
    /// its replicas' ranges).
    pub fn max_seq_len(&self, name: &str) -> Option<usize> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .and_then(|e| e.replicas.iter().map(|r| r.seq_len()).min())
    }

    /// Replica range of `name` (`min..=max`).
    pub fn replica_range(&self, name: &str) -> Option<(usize, usize)> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .map(|e| (e.min_replicas, e.max_replicas))
    }

    /// Latency SLO of `name` in milliseconds, if configured.
    pub fn slo_ms(&self, name: &str) -> Option<f64> {
        self.entries.iter().find(|e| e.name == name).and_then(|e| e.slo_ms)
    }

    /// Consume the registry into router-ready model groups.
    pub fn into_groups(self) -> Vec<ModelGroup> {
        self.entries
            .into_iter()
            .map(|e| ModelGroup {
                model: e.name,
                replicas: e.replicas,
                weight: e.weight,
                min_replicas: e.min_replicas,
                max_replicas: e.max_replicas,
                slo_ms: e.slo_ms,
                factory: e.factory,
                cost: e.cost,
                escalate_to: e.escalate_to,
                escalate_margin: e.escalate_margin,
            })
            .collect()
    }
}

/// Shared validation of an autoscale range.
fn check_range(
    name: &str,
    min_replicas: usize,
    max_replicas: usize,
    slo_ms: Option<f64>,
) -> Result<(), String> {
    if max_replicas < min_replicas {
        return Err(format!(
            "model {name:?}: max replicas {max_replicas} below min {min_replicas}"
        ));
    }
    if let Some(slo) = slo_ms {
        if !(slo.is_finite() && slo > 0.0) {
            return Err(format!("model {name:?}: SLO must be a positive latency, got {slo}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registers_presets_with_shared_groups() {
        let mut reg = ModelRegistry::new();
        reg.register("tiny", "tiny", 2, 2, 7).unwrap();
        reg.register("small", "small", 1, 1, 11).unwrap();
        assert_eq!(reg.len(), 2);
        // names() sorts for a deterministic listing (registration order
        // was tiny, small)
        assert_eq!(reg.names(), vec!["small", "tiny"]);
        assert_eq!(reg.geometry("tiny"), Geometry::preset("tiny"));
        assert_eq!(reg.preset("small"), Some("small"));
        assert_eq!(reg.weight("tiny"), Some(2));
        assert_eq!(reg.max_seq_len("small"), Some(Geometry::preset("small").unwrap().m));
        assert_eq!(reg.geometry("nope"), None);
        let groups = reg.into_groups();
        assert_eq!(groups[0].replicas.len(), 2);
        assert_eq!(groups[1].model, "small");
    }

    #[test]
    fn rejects_bad_configurations() {
        let mut reg = ModelRegistry::new();
        reg.register("tiny", "tiny", 1, 1, 7).unwrap();
        assert!(reg.register("tiny", "small", 1, 1, 7).is_err(), "duplicate id");
        assert!(reg.register("x", "gpt5", 1, 1, 7).is_err(), "unknown preset");
        assert!(reg.register("y", "tiny", 0, 1, 7).is_err(), "zero replicas");
        assert!(reg.register("z", "tiny", 1, 0, 7).is_err(), "zero weight");
        assert!(reg.register("", "tiny", 1, 1, 7).is_err(), "empty id");
        assert!(reg.register_group("g", vec![], 1).is_err(), "empty custom group");
        assert!(
            reg.register_scaled("r", "tiny", 3, 2, 1, Some(5.0), 7).is_err(),
            "max below min"
        );
        assert!(
            reg.register_scaled("r", "tiny", 1, 2, 1, Some(-1.0), 7).is_err(),
            "negative SLO"
        );
        assert!(
            reg.register_scaled("r", "tiny", 1, 2, 1, Some(f64::NAN), 7).is_err(),
            "NaN SLO"
        );
        assert_eq!(reg.len(), 1, "failed registrations leave no residue");
    }

    #[test]
    fn scaled_registration_starts_at_min_and_carries_the_range() {
        let mut reg = ModelRegistry::new();
        reg.register_scaled("tiny", "tiny", 1, 4, 2, Some(12.5), 7).unwrap();
        assert_eq!(reg.replica_range("tiny"), Some((1, 4)));
        assert_eq!(reg.slo_ms("tiny"), Some(12.5));
        assert_eq!(reg.replica_range("fixed"), None);
        let groups = reg.into_groups();
        let g = &groups[0];
        assert_eq!(g.replicas.len(), 1, "the group starts at min replicas");
        assert!(g.scalable());
        // the factory spawns more identical replicas on demand
        let extra = g.factory.as_ref().unwrap()().unwrap();
        assert_eq!(extra.seq_len(), g.replicas[0].seq_len());
        assert_eq!(extra.min_seq_len(), g.replicas[0].min_seq_len());
    }

    #[test]
    fn preset_groups_carry_a_cost_model_custom_groups_do_not() {
        use crate::coordinator::engine::FunctionalEngine;
        use crate::sim::HwConfig;
        let mut reg = ModelRegistry::new();
        reg.register("tiny", "tiny", 1, 1, 7).unwrap();
        let tiny_replica: Arc<dyn EngineReplica> =
            Arc::new(FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap());
        reg.register_group("custom", vec![tiny_replica], 1).unwrap();
        let groups = reg.into_groups();
        let cm = groups[0].cost.as_ref().expect("preset group builds a cost model");
        let geo = Geometry::preset("tiny").unwrap();
        // shared model predicts exactly what the sized-to simulator does
        assert_eq!(
            cm.predict_cycles(geo.m),
            crate::sim::simulate_encoder_m(&HwConfig::sized_to(&geo), &geo, geo.m, None)
                .total_cycles
        );
        assert!(groups[1].cost.is_none(), "custom groups stay token-charged");
    }

    #[test]
    fn cascade_registration_builds_linked_precision_tiers() {
        let mut reg = ModelRegistry::new();
        reg.register_cascade_scaled("tiny", "tiny", 1, 2, 3, Some(10.0), 7, 500).unwrap();
        assert_eq!(reg.len(), 2, "one cascade call registers both tiers");
        assert_eq!(reg.names(), vec!["tiny", "tiny@int8"]);
        assert_eq!(reg.preset("tiny@int8"), Some("tiny"));
        let geo = Geometry::preset("tiny").unwrap();
        let groups = reg.into_groups();
        assert_eq!(groups[0].escalate_to.as_deref(), Some("tiny@int8"));
        assert_eq!(groups[0].escalate_margin, 500);
        assert!(groups[1].escalate_to.is_none(), "the INT8 tier is terminal");
        let c4 = groups[0].cost.as_ref().expect("front tier carries a cost model");
        let c8 = groups[1].cost.as_ref().expect("sibling carries a cost model");
        assert!(
            c4.predict_cycles(geo.m) < c8.predict_cycles(geo.m),
            "the INT4 tier prices the same die below INT8"
        );
        // both tiers serve the same request range and both factories
        // spawn working replicas off the shared bundle
        let toks: Vec<i32> = (0..geo.m.min(6)).map(|i| (i * 7 % 60) as i32).collect();
        for g in &groups {
            assert!(g.scalable());
            let spawned = g.factory.as_ref().unwrap()().unwrap();
            let a = g.replicas[0].predict(&toks).unwrap();
            let b = spawned.predict(&toks).unwrap();
            assert_eq!(a.logits, b.logits, "{}: factory replica diverged", g.model);
        }
    }

    #[test]
    fn cascade_registration_rejects_id_collisions_and_bad_margins() {
        let mut reg = ModelRegistry::new();
        reg.register("busy@int8", "tiny", 1, 1, 7).unwrap();
        assert!(
            reg.register_cascade_scaled("busy", "tiny", 1, 1, 1, None, 7, 0).is_err(),
            "sibling id collision"
        );
        assert!(
            reg.register_cascade_scaled("m", "tiny", 1, 1, 1, None, 7, -1).is_err(),
            "negative margin"
        );
        assert_eq!(reg.len(), 1, "failed cascade registrations leave no residue");
        reg.register_cascade_scaled("tiny", "tiny", 1, 1, 1, None, 7, 0).unwrap();
        assert!(
            reg.register("tiny@int8", "tiny", 1, 1, 7).is_err(),
            "the sibling id is reserved"
        );
    }

    #[test]
    fn fixed_groups_are_not_scalable() {
        let mut reg = ModelRegistry::new();
        reg.register("tiny", "tiny", 2, 1, 7).unwrap();
        assert_eq!(reg.replica_range("tiny"), Some((2, 2)));
        assert_eq!(reg.slo_ms("tiny"), None);
        assert!(!reg.into_groups().remove(0).scalable());
    }
}
