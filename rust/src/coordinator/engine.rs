//! Engine replicas: the per-accelerator end of the parallel serving
//! pipeline (DESIGN.md §2).
//!
//! A *replica* models one SwiftTron accelerator attached to the host.
//! The [`Router`](super::Router) batches incoming requests into dispatch
//! groups and the [`ReplicaPool`](super::ReplicaPool) fans each group
//! out across N replicas on the in-repo `util` thread pool; every
//! replica executes its share of the group serially, exactly as the
//! hardware would (the array is loaded per sentence).  Anything that
//! implements [`EngineReplica`] can sit in the pool; two
//! implementations ship:
//!
//! * [`InferenceEngine`] — the artifact-backed path (paper Fig. 1b):
//!   tokens -> embedding + positional add (host f32, outside the
//!   accelerator per Fig. 4's "inputs taken after positional encoding")
//!   -> symmetric INT8 quantization at the calibrated `s_in` -> PJRT
//!   execution of the AOT integer encoder artifact -> integer mean-pool
//!   + INT8 classifier head (`quant::i_matmul`) -> argmax label.
//! * [`FunctionalEngine`] — the same integer request path executed by
//!   the in-crate functional model (`sim::functional`) on synthetic
//!   weights: no artifacts, no PJRT, no external dependencies.  It
//!   drives the serving tests and the replica-scaling bench offline.
//!
//! Each prediction carries the cycle-accurate SwiftTron latency for the
//! same computation; the pool aggregates it per replica as virtual time
//! next to wall-clock throughput (`coordinator::metrics`).
//!
//! Sequence length is a *per-request* property (DESIGN.md §6): a request
//! of `m_eff` tokens is validated against the replica's serveable range
//! (`min_seq_len()..=seq_len()`) with a typed [`RequestError`], and
//! variable-length backends run exactly `m_eff` rows — numerics over the
//! resident Workspace arena, simulated cycles via
//! `sim::simulate_encoder_m` at the live length.

use crate::model::{Blob, Geometry, Manifest};
use crate::quant::i_matmul;
use crate::runtime::{Engine, Executable, Tensor};
use crate::sim::functional::{
    encoder_forward_ws, encoder_forward_ws_int4, synthetic_consts, LayerWeights,
    LayerWeightsInt4, Workspace,
};
use crate::sim::{simulate_encoder_m, CostModel, HwConfig};
use crate::util::rng::Rng;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Typed request-validation error of the serving path (DESIGN.md §6).
/// Replicas reject malformed requests with a variant instead of a
/// formatted string, so callers can branch on the cause; the wire layer
/// (`Response.error`) renders it via `Display`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// Token count outside the replica's serveable range.  Fixed-shape
    /// backends (the PJRT artifact) have `min == max`; variable-length
    /// backends accept `1..=max`.
    BadLength { got: usize, min: usize, max: usize },
    /// Token id outside the embedding table.
    BadToken { token: i32, vocab: usize },
    /// Backend failure (PJRT runtime, artifact load, replica panic).
    Backend(String),
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::BadLength { got, min, max } => {
                write!(f, "request length {got} outside serveable range {min}..={max}")
            }
            RequestError::BadToken { token, vocab } => {
                write!(f, "token {token} out of vocab {vocab}")
            }
            RequestError::Backend(e) => write!(f, "backend error: {e}"),
        }
    }
}

impl std::error::Error for RequestError {}

impl From<String> for RequestError {
    fn from(e: String) -> Self {
        RequestError::Backend(e)
    }
}

/// `?`-compatibility for the `Result<_, String>` CLI/example drivers.
impl From<RequestError> for String {
    fn from(e: RequestError) -> String {
        e.to_string()
    }
}

/// One engine replica: the unit of parallelism of the serving layer.
/// A replica owns everything needed to serve a request end to end and
/// is driven from one pool thread at a time.
pub trait EngineReplica: Send + Sync {
    /// Run one request end to end (numerics + simulated accelerator
    /// time).  The token count is the request's live sequence length
    /// `m_eff`; implementations validate it against their serveable
    /// range and reject with [`RequestError::BadLength`] otherwise.
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError>;

    /// Maximum sequence length `m` this replica's model can serve (the
    /// geometry the arena / artifact was sized to).
    fn seq_len(&self) -> usize;

    /// Shortest request this replica accepts.  Defaults to
    /// [`seq_len`](EngineReplica::seq_len) (fixed-shape backends);
    /// variable-length backends override it to 1.
    fn min_seq_len(&self) -> usize {
        self.seq_len()
    }
}

#[derive(Clone, Debug)]
pub struct Prediction {
    pub label: usize,
    pub logits: Vec<i64>,
    /// simulated accelerator latency for this inference
    pub accel_cycles: u64,
    pub accel_ms: f64,
}

pub struct InferenceEngine {
    pub geo: Geometry,
    exe_int8: Executable,
    exe_f32: Option<Executable>,
    emb: Vec<f32>,    // (vocab, d)
    pos: Vec<f32>,    // (m, d)
    q_w_head: Vec<i32>, // (d, 2)
    q_b_head: Vec<i32>,
    f_w_head: Vec<f32>,
    f_b_head: Vec<f32>,
    s_in: f64,
    vocab: usize,
    hw: HwConfig,
    accel_cycles: u64,
}

impl InferenceEngine {
    /// Build from the artifacts directory (tiny preset).
    pub fn load(
        artifacts: &Path,
        engine: &Engine,
        hw: HwConfig,
    ) -> Result<InferenceEngine, String> {
        let manifest = Manifest::load(artifacts)?;
        let preset = manifest.preset("tiny")?;
        let geo = preset.geometry;
        let blob = Blob::load(&manifest.blob_prefix("tiny")?)?;
        let exe_int8 = engine.load(&manifest.artifact_path("tiny", "int8")?)?;
        let exe_f32 = manifest
            .artifact_path("tiny", "f32")
            .ok()
            .and_then(|p| engine.load(&p).ok());
        let sim = simulate_encoder_m(&hw, &geo, geo.m, None);
        Ok(InferenceEngine {
            geo,
            exe_int8,
            exe_f32,
            emb: blob.f32("emb")?,
            pos: blob.f32("pos")?,
            q_w_head: blob.i32("q_w_head")?,
            q_b_head: blob.i32("q_b_head")?,
            f_w_head: blob.f32("f_w_head")?,
            f_b_head: blob.f32("f_b_head")?,
            s_in: preset.s_in.ok_or("tiny preset missing s_in")?,
            vocab: blob.shape("emb")?[0],
            hw,
            accel_cycles: sim.total_cycles,
        })
    }

    /// Embedding + positional add + INT8 quantization (host side).  The
    /// AOT artifact is compiled for exactly `geo.m` rows, so any other
    /// token count is a typed [`RequestError::BadLength`].
    pub fn embed_quantize(&self, tokens: &[i32]) -> Result<Vec<i32>, RequestError> {
        let (m, d) = (self.geo.m, self.geo.d);
        if tokens.len() != m {
            return Err(RequestError::BadLength { got: tokens.len(), min: m, max: m });
        }
        let mut q = vec![0i32; m * d];
        for (i, &t) in tokens.iter().enumerate() {
            let ti = t as usize;
            if t < 0 || ti >= self.vocab {
                return Err(RequestError::BadToken { token: t, vocab: self.vocab });
            }
            for j in 0..d {
                let x = self.emb[ti * d + j] as f64 + self.pos[i * d + j] as f64;
                q[i * d + j] = (x / self.s_in).round().clamp(-128.0, 127.0) as i32;
            }
        }
        Ok(q)
    }

    /// Integer mean-pool (shift when m is a power of two) + INT8 head.
    fn head(&self, q_out: &[i32]) -> (usize, Vec<i64>) {
        integer_head(q_out, &self.q_w_head, &self.q_b_head, self.geo.m, self.geo.d)
    }

    /// Full integer-path prediction via the PJRT artifact.
    pub fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
        let (m, d) = (self.geo.m, self.geo.d);
        let q_x = self.embed_quantize(tokens)?;
        let out = self
            .exe_int8
            .run_i32(&[Tensor::i32(&[m, d], q_x)], &[m, d])
            .map_err(RequestError::Backend)?;
        let (label, logits) = self.head(out.as_i32().unwrap());
        Ok(Prediction {
            label,
            logits,
            accel_cycles: self.accel_cycles,
            accel_ms: self.hw.cycles_to_ms(self.accel_cycles),
        })
    }

    /// Float-twin prediction (accuracy baseline).
    pub fn predict_f32(&self, tokens: &[i32]) -> Result<usize, String> {
        let exe = self.exe_f32.as_ref().ok_or("no f32 artifact")?;
        let (m, d) = (self.geo.m, self.geo.d);
        let mut x = vec![0f32; m * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            for j in 0..d {
                x[i * d + j] = self.emb[t * d + j] + self.pos[i * d + j];
            }
        }
        let out = exe.run_f32(&[Tensor::f32(&[m, d], x)], &[m, d])?;
        let h = out.as_f32().unwrap();
        let n_cls = self.f_b_head.len();
        let mut pooled = vec![0f64; d];
        for j in 0..d {
            pooled[j] = (0..m).map(|i| h[i * d + j] as f64).sum::<f64>() / m as f64;
        }
        let mut logits = vec![0f64; n_cls];
        for (c, l) in logits.iter_mut().enumerate() {
            *l = self.f_b_head[c] as f64
                + (0..d).map(|j| pooled[j] * self.f_w_head[j * n_cls + c] as f64).sum::<f64>();
        }
        Ok((0..n_cls).max_by_key(|&i| (logits[i] * 1e9) as i64).unwrap_or(0))
    }

    pub fn hw(&self) -> &HwConfig {
        &self.hw
    }
}

impl EngineReplica for InferenceEngine {
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
        InferenceEngine::predict(self, tokens)
    }

    fn seq_len(&self) -> usize {
        self.geo.m
    }
}

/// Shared integer readout: mean-pool over rows + INT8 classifier head.
fn integer_head(
    q_out: &[i32],
    w_head: &[i32],
    b_head: &[i32],
    m: usize,
    d: usize,
) -> (usize, Vec<i64>) {
    let mut pooled = vec![0i32; d];
    for j in 0..d {
        let mut s: i64 = 0;
        for i in 0..m {
            s += q_out[i * d + j] as i64;
        }
        pooled[j] = crate::quant::div_floor(s, m as i64) as i32;
    }
    let n_cls = b_head.len();
    let mut logits32 = vec![0i32; n_cls];
    i_matmul(&pooled, w_head, Some(b_head), 1, d, n_cls, &mut logits32);
    let logits: Vec<i64> = logits32.iter().map(|&v| v as i64).collect();
    let label = (0..n_cls).max_by_key(|&i| logits[i]).unwrap_or(0);
    (label, logits)
}

/// Immutable synthetic model bundle: geometry, per-layer weights,
/// embedding/positional tables, and the classifier head, generated
/// deterministically from a seed.  Replicas of the same registry entry
/// are *one model*, so the bundle lives once behind an `Arc` and every
/// [`FunctionalEngine`] replica owns only its private [`Workspace`]
/// arena — a RoBERTa-sized weight set is paid once per model, not once
/// per replica (DESIGN.md §8).
pub struct SyntheticModel {
    pub geo: Geometry,
    layers: Vec<(LayerWeights, crate::model::LayerConsts)>,
    emb: Vec<i32>, // (vocab, d), INT8-coded
    pos: Vec<i32>, // (m, d), small ints
    w_head: Vec<i32>, // (d, 2)
    b_head: Vec<i32>,
    vocab: usize,
}

impl SyntheticModel {
    /// Build the bundle for a named geometry preset.  Same `(preset,
    /// seed)` => identical model (weights, embedding, head).
    pub fn build(preset: &str, seed: u64) -> Result<SyntheticModel, String> {
        let geo =
            Geometry::preset(preset).ok_or_else(|| format!("unknown preset {preset:?}"))?;
        Ok(SyntheticModel::build_geo(&geo, seed))
    }

    /// Build the bundle for an explicit geometry (tests use this to run
    /// a preset's `d`/`heads`/`d_ff` numerics at a reduced depth).
    pub fn build_geo(geo: &Geometry, seed: u64) -> SyntheticModel {
        let mut rng = Rng::new(seed);
        let vocab = 64;
        let emb: Vec<i32> =
            (0..vocab * geo.d).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let pos: Vec<i32> =
            (0..geo.m * geo.d).map(|_| rng.range_i64(-27, 27) as i32).collect();
        let layers = (0..geo.layers)
            .map(|_| (LayerWeights::synthetic(&mut rng, geo), synthetic_consts(geo)))
            .collect();
        let w_head: Vec<i32> =
            (0..geo.d * 2).map(|_| rng.range_i64(-127, 127) as i32).collect();
        let b_head: Vec<i32> = (0..2).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
        SyntheticModel { geo: *geo, layers, emb, pos, w_head, b_head, vocab }
    }

    /// Quantize this model's layer stack onto the packed INT4 grid
    /// (DESIGN.md §14).  The embedding/positional tables and the
    /// classifier head are host-side and stay shared with the INT8
    /// tier; only the accelerator-resident weight matrices change
    /// precision, so an INT4 replica group derives from the *same*
    /// `Arc<SyntheticModel>` as its INT8 siblings — one weight bundle,
    /// two precisions.
    pub fn quantize_int4(&self) -> Vec<(LayerWeightsInt4, crate::model::LayerConsts)> {
        self.layers
            .iter()
            .map(|(w, c)| (LayerWeightsInt4::quantize(w, &self.geo), c.clone()))
            .collect()
    }
}

/// Artifact-free engine replica: the bit-exact functional model
/// (`sim::functional`) over synthetic weights, with the same integer
/// request path and virtual-time accounting as [`InferenceEngine`].
///
/// Every replica built from the same `(preset, seed)` is an identical
/// model, so a pool of them is a true replica set — and replicas built
/// via [`FunctionalEngine::replica_group`] share one [`SyntheticModel`]
/// behind an `Arc`.  Above the [`crate::quant::PAR_MIN_MACS`] threshold
/// its contractions take the row-tiled parallel `i_matmul`; the tiny
/// preset stays below it, so replica-level parallelism is the only
/// concurrency in play there (no nested oversubscription in the scaling
/// bench).
///
/// Unlike the fixed-shape artifact path, this replica serves any live
/// sequence length `1..=geo.m` (DESIGN.md §6): the forward pass runs
/// over a resident [`Workspace`] arena sized once to `geo.m` and sliced
/// to the request, so short requests cost proportionally fewer host
/// cycles *and* proportionally fewer simulated accelerator cycles
/// (`sim::simulate_encoder_m` at the live `m_eff`).
pub struct FunctionalEngine {
    model: Arc<SyntheticModel>,
    hw: HwConfig,
    /// Resident scratch arena for the allocation-free forward pass.
    /// Uncontended in the pool's one-thread-per-replica regime; the
    /// Mutex only matters when one engine object backs several pool
    /// slots (legal, e.g. the PJRT serving test's shared Arc).
    ws: Mutex<Workspace>,
    /// Closed-form cycle accounting (`sim::cost`, DESIGN.md §12).
    /// Worst-case sqrt timing (the paper default) is data-independent,
    /// so the model predicts every request exactly; replicas of one
    /// registry group share a single build behind the `Arc`.
    cost: Arc<CostModel>,
    /// The packed INT4 layer stack when this replica serves the
    /// low-precision cascade tier (DESIGN.md §14): quantized once per
    /// group from the shared [`SyntheticModel`] and shared across the
    /// group's replicas.  `None` => the replica runs the INT8 stack.
    int4: Option<Arc<Vec<(LayerWeightsInt4, crate::model::LayerConsts)>>>,
}

impl FunctionalEngine {
    /// Build a synthetic replica for a geometry preset.  Same seed =>
    /// identical replica (weights, embedding, head).
    pub fn synthetic(preset: &str, seed: u64, hw: HwConfig) -> Result<FunctionalEngine, String> {
        Ok(FunctionalEngine::from_model(Arc::new(SyntheticModel::build(preset, seed)?), hw))
    }

    /// Build a replica over an existing (shared) model bundle.  Builds
    /// its own [`CostModel`]; panics on a configuration the simulator
    /// cannot run (the pre-CostModel code simulated the full length
    /// here and panicked on the same configurations).
    pub fn from_model(model: Arc<SyntheticModel>, hw: HwConfig) -> FunctionalEngine {
        let cost = CostModel::build(&hw, &model.geo)
            .unwrap_or_else(|e| panic!("unsimulatable hardware configuration: {e}"));
        FunctionalEngine::from_model_with_cost(model, hw, Arc::new(cost))
    }

    /// Build a replica over a shared model bundle *and* a shared
    /// prebuilt [`CostModel`] — what the registry uses so N replicas of
    /// one group pay for one build, not N.
    pub fn from_model_with_cost(
        model: Arc<SyntheticModel>,
        hw: HwConfig,
        cost: Arc<CostModel>,
    ) -> FunctionalEngine {
        // host-execution knob (DESIGN.md §7): head-parallel fused
        // attention, selectable back to the serial loop via HwConfig —
        // numerics are bit-exact either way
        let mut ws = Workspace::new(&model.geo);
        ws.set_attn_heads_parallel(hw.attn_heads_parallel);
        FunctionalEngine { model, hw, ws: Mutex::new(ws), cost, int4: None }
    }

    /// Build an **INT4-tier** replica (DESIGN.md §14): same shared
    /// model bundle and host-side embed/head path, the encoder running
    /// the packed INT4 stack (`layers4`, quantized once per group via
    /// [`SyntheticModel::quantize_int4`]) on the INT4 hardware instance
    /// (`hw` is typically [`HwConfig::int4_variant`] of the group's
    /// INT8 instance; `cost` must be built on the same `hw` so cycle
    /// ledgers price the low-precision work correctly).
    pub fn from_model_int4(
        model: Arc<SyntheticModel>,
        layers4: Arc<Vec<(LayerWeightsInt4, crate::model::LayerConsts)>>,
        hw: HwConfig,
        cost: Arc<CostModel>,
    ) -> FunctionalEngine {
        let mut e = FunctionalEngine::from_model_with_cost(model, hw, cost);
        e.int4 = Some(layers4);
        e
    }

    /// Whether this replica serves the packed INT4 tier.
    pub fn is_int4(&self) -> bool {
        self.int4.is_some()
    }

    /// Build `n` identical replicas of one synthetic model — the
    /// weights are generated once and shared, each replica gets its own
    /// arena, and all replicas share one [`CostModel`] build.  This is
    /// what [`super::registry::ModelRegistry`] hosts per model id.
    pub fn replica_group(
        preset: &str,
        seed: u64,
        hw: HwConfig,
        n: usize,
    ) -> Result<Vec<Arc<dyn EngineReplica>>, String> {
        let model = Arc::new(SyntheticModel::build(preset, seed)?);
        let cost = Arc::new(CostModel::build(&hw, &model.geo)?);
        Ok((0..n)
            .map(|_| {
                Arc::new(FunctionalEngine::from_model_with_cost(
                    Arc::clone(&model),
                    hw,
                    Arc::clone(&cost),
                )) as Arc<dyn EngineReplica>
            })
            .collect())
    }

    /// Geometry of the resident model.
    pub fn geometry(&self) -> &Geometry {
        &self.model.geo
    }

    /// The replica's cost model (shared across a registry group).
    pub fn cost_model(&self) -> &Arc<CostModel> {
        &self.cost
    }

    /// Simulated accelerator cycles for one request of live length
    /// `m_eff` whose forward pass produced `sqrt_iters`.
    fn accel_cycles(&self, m_eff: usize, sqrt_iters: &[u32]) -> u64 {
        if self.hw.worst_case_sqrt {
            // data-independent: the closed form is exact (validated
            // against the simulator at build time)
            self.cost.predict_cycles(m_eff)
        } else {
            simulate_encoder_m(&self.hw, &self.model.geo, m_eff, Some(sqrt_iters)).total_cycles
        }
    }
}

impl EngineReplica for FunctionalEngine {
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
        let model = &*self.model;
        let d = model.geo.d;
        let m_eff = tokens.len();
        if m_eff == 0 || m_eff > model.geo.m {
            return Err(RequestError::BadLength { got: m_eff, min: 1, max: model.geo.m });
        }
        // integer embedding + positional add, saturated to INT8
        let mut q_x = vec![0i32; m_eff * d];
        for (i, &t) in tokens.iter().enumerate() {
            let ti = t as usize;
            if t < 0 || ti >= model.vocab {
                return Err(RequestError::BadToken { token: t, vocab: model.vocab });
            }
            for j in 0..d {
                q_x[i * d + j] =
                    (model.emb[ti * d + j] + model.pos[i * d + j]).clamp(-128, 127);
            }
        }
        let mut q_out = vec![0i32; m_eff * d];
        let mut sqrt_iters = Vec::with_capacity(2 * m_eff * model.layers.len());
        {
            let mut ws = self.ws.lock().unwrap();
            match &self.int4 {
                Some(layers4) => encoder_forward_ws_int4(
                    &q_x,
                    layers4,
                    &model.geo,
                    m_eff,
                    &mut ws,
                    &mut q_out,
                    &mut sqrt_iters,
                ),
                None => encoder_forward_ws(
                    &q_x,
                    &model.layers,
                    &model.geo,
                    m_eff,
                    &mut ws,
                    &mut q_out,
                    &mut sqrt_iters,
                ),
            }
        }
        let (label, logits) = integer_head(&q_out, &model.w_head, &model.b_head, m_eff, d);
        let cycles = self.accel_cycles(m_eff, &sqrt_iters);
        Ok(Prediction {
            label,
            logits,
            accel_cycles: cycles,
            accel_ms: self.hw.cycles_to_ms(cycles),
        })
    }

    fn seq_len(&self) -> usize {
        self.model.geo.m
    }

    fn min_seq_len(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn functional_engine_is_deterministic_per_seed() {
        let a = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        let b = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        let tokens: Vec<i32> = (0..a.seq_len()).map(|i| (i % 60) as i32).collect();
        let pa = EngineReplica::predict(&a, &tokens).unwrap();
        let pb = EngineReplica::predict(&b, &tokens).unwrap();
        assert_eq!(pa.label, pb.label);
        assert_eq!(pa.logits, pb.logits);
        assert!(pa.accel_cycles > 0);
        assert!(pa.accel_ms > 0.0);
    }

    #[test]
    fn attn_parallel_knob_is_numerically_invisible() {
        // the HwConfig host knob selects the serial head loop; labels,
        // logits and simulated cycles must be bit-identical either way
        let on = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        let off_hw = HwConfig { attn_heads_parallel: false, ..HwConfig::paper() };
        let off = FunctionalEngine::synthetic("tiny", 7, off_hw).unwrap();
        let tokens: Vec<i32> = (0..on.seq_len()).map(|i| (i % 60) as i32).collect();
        let a = EngineReplica::predict(&on, &tokens).unwrap();
        let b = EngineReplica::predict(&off, &tokens).unwrap();
        assert_eq!(a.label, b.label);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.accel_cycles, b.accel_cycles);
    }

    #[test]
    fn functional_engine_rejects_bad_requests_with_typed_errors() {
        let e = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        let max = e.seq_len();
        assert_eq!(
            EngineReplica::predict(&e, &[]).unwrap_err(),
            RequestError::BadLength { got: 0, min: 1, max }
        );
        let too_long = vec![0i32; max + 1];
        assert_eq!(
            EngineReplica::predict(&e, &too_long).unwrap_err(),
            RequestError::BadLength { got: max + 1, min: 1, max }
        );
        let mut tokens: Vec<i32> = vec![0; max];
        tokens[0] = 9999;
        assert_eq!(
            EngineReplica::predict(&e, &tokens).unwrap_err(),
            RequestError::BadToken { token: 9999, vocab: 64 }
        );
        tokens[0] = -1;
        assert!(matches!(
            EngineReplica::predict(&e, &tokens),
            Err(RequestError::BadToken { token: -1, .. })
        ));
    }

    #[test]
    fn replica_group_shares_one_model_and_stays_identical() {
        // replicas built as a group share the weight bundle (one Arc)
        // and agree bit for bit with a standalone engine of the same
        // (preset, seed)
        let group = FunctionalEngine::replica_group("tiny", 7, HwConfig::paper(), 3).unwrap();
        assert_eq!(group.len(), 3);
        let lone = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        let tokens: Vec<i32> = (0..lone.seq_len()).map(|i| (i % 60) as i32).collect();
        let want = EngineReplica::predict(&lone, &tokens).unwrap();
        for r in &group {
            let got = r.predict(&tokens).unwrap();
            assert_eq!(got.label, want.label);
            assert_eq!(got.logits, want.logits);
            assert_eq!(got.accel_cycles, want.accel_cycles);
        }
    }

    #[test]
    fn int4_tier_replica_is_deterministic_and_cheaper() {
        // The cascade front tier: replicas share one weight bundle and
        // one packed-INT4 lane set; predictions are deterministic
        // across replicas and the low-precision pass costs fewer
        // simulated cycles than the INT8 sibling on the same request.
        let model = Arc::new(SyntheticModel::build("tiny", 7).unwrap());
        let hw8 = HwConfig::paper();
        let hw4 = hw8.int4_variant();
        let cost8 = Arc::new(CostModel::build(&hw8, &model.geo).unwrap());
        let cost4 = Arc::new(CostModel::build(&hw4, &model.geo).unwrap());
        let layers4 = Arc::new(model.quantize_int4());
        let int8 = FunctionalEngine::from_model_with_cost(Arc::clone(&model), hw8, cost8);
        let a = FunctionalEngine::from_model_int4(
            Arc::clone(&model),
            Arc::clone(&layers4),
            hw4,
            Arc::clone(&cost4),
        );
        let b = FunctionalEngine::from_model_int4(model, layers4, hw4, cost4);
        assert!(a.is_int4() && b.is_int4() && !int8.is_int4());
        let tokens: Vec<i32> = (0..a.seq_len()).map(|i| (i % 60) as i32).collect();
        let pa = EngineReplica::predict(&a, &tokens).unwrap();
        let pb = EngineReplica::predict(&b, &tokens).unwrap();
        assert_eq!(pa.label, pb.label);
        assert_eq!(pa.logits, pb.logits, "INT4 replicas agree bit for bit");
        assert_eq!(pa.accel_cycles, pb.accel_cycles);
        let p8 = EngineReplica::predict(&int8, &tokens).unwrap();
        assert_eq!(pa.logits.len(), p8.logits.len());
        assert!(
            pa.accel_cycles < p8.accel_cycles,
            "INT4 pass must be cheaper than INT8 ({} vs {})",
            pa.accel_cycles,
            p8.accel_cycles
        );
    }

    #[test]
    fn functional_engine_serves_variable_lengths() {
        // Short requests are legal (min_seq_len == 1), cost fewer
        // simulated cycles, and stay deterministic over the warm arena.
        let e = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        let m = e.seq_len();
        assert_eq!(e.min_seq_len(), 1);
        let full: Vec<i32> = (0..m).map(|i| (i % 60) as i32).collect();
        let p_full = EngineReplica::predict(&e, &full).unwrap();
        let p_short = EngineReplica::predict(&e, &full[..m / 4]).unwrap();
        assert!(
            p_short.accel_cycles < p_full.accel_cycles,
            "quarter-length request must cost fewer simulated cycles \
             ({} vs {})",
            p_short.accel_cycles,
            p_full.accel_cycles
        );
        let again = EngineReplica::predict(&e, &full[..m / 4]).unwrap();
        assert_eq!(again.logits, p_short.logits);
        assert_eq!(again.accel_cycles, p_short.accel_cycles);
    }
}
