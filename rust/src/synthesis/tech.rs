//! 65 nm CMOS technology constants.
//!
//! Values are textbook/industry-typical figures for a 65 nm low-power
//! standard-cell library (the paper does not name its library):
//! * NAND2-equivalent gate area ~1.44 um^2 (=> ~0.7 Mgate/mm^2),
//! * FO4 inverter delay ~25 ps (gate-delay unit for timing),
//! * dynamic energy ~1.5 fJ per gate-equivalent toggle at 1.2 V,
//! * leakage ~2 nW per gate-equivalent.

#[derive(Clone, Copy, Debug)]
pub struct Tech65 {
    /// area of one NAND2-equivalent gate, in um^2
    pub ge_area_um2: f64,
    /// FO4 delay in picoseconds (one "gate delay")
    pub fo4_ps: f64,
    /// dynamic energy per GE toggle, femtojoules
    pub e_dyn_fj: f64,
    /// leakage power per GE, nanowatts
    pub p_leak_nw: f64,
    /// supply voltage (volts), recorded for the report header
    pub vdd: f64,
}

impl Tech65 {
    pub fn new() -> Tech65 {
        Tech65 { ge_area_um2: 1.44, fo4_ps: 25.0, e_dyn_fj: 1.5, p_leak_nw: 2.0, vdd: 1.2 }
    }

    /// Area in mm^2 of `ge` gate equivalents.
    pub fn area_mm2(&self, ge: f64) -> f64 {
        ge * self.ge_area_um2 * 1e-6
    }

    /// Dynamic power in watts of `ge` gates toggling with `activity`
    /// (0..1) at `freq_hz`.
    pub fn dyn_power_w(&self, ge: f64, activity: f64, freq_hz: f64) -> f64 {
        ge * activity * self.e_dyn_fj * 1e-15 * freq_hz
    }

    /// Leakage power in watts of `ge` gates.
    pub fn leak_power_w(&self, ge: f64) -> f64 {
        ge * self.p_leak_nw * 1e-9
    }

    /// Critical-path delay in ns of a path of `gates` gate delays.
    pub fn delay_ns(&self, gates: f64) -> f64 {
        gates * self.fo4_ps * 1e-3
    }
}

impl Default for Tech65 {
    fn default() -> Self {
        Tech65::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn density_near_700k_ge_per_mm2() {
        let t = Tech65::new();
        let ge_per_mm2 = 1.0 / (t.ge_area_um2 * 1e-6);
        assert!((600_000.0..800_000.0).contains(&ge_per_mm2));
    }

    #[test]
    fn power_scales_linearly() {
        let t = Tech65::new();
        let p1 = t.dyn_power_w(1000.0, 0.2, 143e6);
        let p2 = t.dyn_power_w(2000.0, 0.2, 143e6);
        assert!((p2 / p1 - 2.0).abs() < 1e-12);
    }
}
