//! Multi-model multi-tenant serving integration tests (ISSUE 4): the
//! registry's per-model replica groups behind one router, model-keyed
//! routing with per-model metrics, and the acceptance claim — mixed
//! `roberta_base` + `deit_s` + `tiny` traffic through one pool whose
//! served-token shares land within 10% of the configured fair-share
//! weights (DESIGN.md §8, the configuration `examples/serving.rs`
//! drives).

mod common;

use common::canonical_tokens;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};
use swifttron::coordinator::{
    BatchPolicy, Batcher, EngineReplica, FunctionalEngine, Metrics, ModelRegistry, Prediction,
    ReplicaPool, Request, RequestError, Router,
};
use swifttron::model::Geometry;
use swifttron::sim::HwConfig;

/// Reference engine for a registry entry: same preset, seed, and
/// sized-to hardware instance, so labels, logits, and virtual time all
/// have to match the served responses exactly.
fn reference(preset: &str, seed: u64) -> FunctionalEngine {
    let geo = Geometry::preset(preset).unwrap();
    FunctionalEngine::synthetic(preset, seed, HwConfig::sized_to(&geo)).unwrap()
}

#[test]
fn router_routes_models_and_accounts_per_model() {
    let mut reg = ModelRegistry::new();
    reg.register("tiny", "tiny", 2, 1, 7).unwrap();
    reg.register("small", "small", 1, 1, 11).unwrap();
    let metrics = Arc::new(Metrics::new());
    let wait = Duration::from_millis(1);
    let policy = BatchPolicy { max_batch: 4, max_wait: wait, bucket_width: 8 };
    let router = Router::start_multi(reg.into_groups(), policy, Arc::clone(&metrics));
    assert_eq!(router.model_names(), vec!["tiny", "small"]);

    let ref_tiny = reference("tiny", 7);
    let ref_small = reference("small", 11);
    let m_tiny = ref_tiny.seq_len();
    let m_small = ref_small.seq_len();

    let mut receivers = Vec::new();
    for i in 0..12 {
        let t_tiny = canonical_tokens(1 + (i * 7) % m_tiny);
        let t_small = canonical_tokens(1 + (i * 11) % m_small);
        let want_tiny = ref_tiny.predict(&t_tiny).unwrap();
        let want_small = ref_small.predict(&t_small).unwrap();
        let (tx, rx) = channel();
        router.submit_to("tiny", t_tiny, tx);
        receivers.push((rx, "tiny", want_tiny));
        let (tx, rx) = channel();
        router.submit_to("small", t_small, tx);
        receivers.push((rx, "small", want_small));
    }
    for (rx, model, want) in receivers {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{model}: {:?}", resp.error);
        assert_eq!(resp.model, model, "response carries the serving model id");
        assert_eq!(resp.label, want.label, "{model} label");
        assert_eq!(resp.logits, want.logits, "{model} logits diverged from reference");
        assert!(
            (resp.accel_ms - want.accel_ms).abs() < 1e-12,
            "{model} virtual time is per-model, per-length"
        );
        match model {
            "tiny" => assert!(resp.replica < 2, "tiny owns global replicas 0..2"),
            _ => assert_eq!(resp.replica, 2, "small owns global replica 2"),
        }
    }

    // unknown model: immediate typed error, counted, never queued
    let (tx, rx) = channel();
    router.submit_to("bert", canonical_tokens(4), tx);
    let resp = rx.recv().unwrap();
    assert!(resp.error.as_deref().unwrap_or("").contains("unknown model"), "{:?}", resp.error);
    assert_eq!(resp.model, "bert");

    router.shutdown();

    assert_eq!(metrics.model_count(), 2);
    assert_eq!(metrics.model_name(0).as_deref(), Some("tiny"));
    let tiny = metrics.model(0);
    let small = metrics.model(1);
    assert_eq!(tiny.requests.load(Ordering::Relaxed), 12);
    assert_eq!(tiny.completed.load(Ordering::Relaxed), 12);
    assert_eq!(small.completed.load(Ordering::Relaxed), 12);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 24);
    assert_eq!(metrics.errors.load(Ordering::Relaxed), 1, "only the unknown model errored");
    assert!(tiny.served_tokens.load(Ordering::Relaxed) > 0);
    assert!(
        small.served_padded_tokens.load(Ordering::Relaxed)
            >= small.served_tokens.load(Ordering::Relaxed),
        "padding never shrinks tokens"
    );
    // per-model padding waste is tracked separately (bucket width 8
    // against mixed lengths pads both models, by different amounts)
    assert!(tiny.padding_waste() > 0.0);
    assert!(small.padding_waste() > 0.0);
    let report = metrics.report();
    assert!(report.contains("model tiny"), "{report}");
    assert!(report.contains("model small"), "{report}");
}

#[test]
fn mixed_preset_traffic_shares_converge_to_weights() {
    // The acceptance configuration: tiny (weight 2), deit_s (1), and
    // roberta_base (1) resident in one pool.  Every model is kept
    // backlogged with equal-cost (one live token, 8-token bucket)
    // requests while the weighted-fair scheduler dispatches; after a
    // fixed number of groups each model's share of served padded
    // tokens must sit within 10% of its weight share.  The loop drives
    // the real batcher + registry replica groups + pool + metrics —
    // only the dispatcher threads are bypassed so the measurement
    // window is deterministic.
    let weights: [u64; 3] = [2, 1, 1];
    let mut reg = ModelRegistry::new();
    reg.register("tiny", "tiny", 1, weights[0], 7).unwrap();
    reg.register("deit_s", "deit_s", 1, weights[1], 7).unwrap();
    reg.register("roberta_base", "roberta_base", 1, weights[2], 7).unwrap();
    let names = ["tiny", "deit_s", "roberta_base"];

    let metrics = Arc::new(Metrics::new());
    metrics.ensure_models(&[("tiny", 2), ("deit_s", 1), ("roberta_base", 1)]);
    let wait = Duration::from_secs(3600);
    let policy = BatchPolicy { max_batch: 4, max_wait: wait, bucket_width: 8 };
    let pool = ReplicaPool::new_multi(reg.into_groups(), Arc::clone(&metrics));
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    batcher.set_model_weights(&weights);

    // Prefill interleaved so every model stays backlogged through all
    // measured dispatches (48 groups x 4 requests; weight shares cap
    // any one model at 96 requests).
    let total_batches = 48usize;
    let per_model = [120usize, 60, 60];
    let mut receivers = Vec::new();
    let mut id = 0u64;
    for i in 0..per_model[0] {
        for (m, &cap) in per_model.iter().enumerate() {
            if i >= cap {
                continue;
            }
            let (tx, rx) = channel();
            id += 1;
            let tokens = vec![(i % 60) as i32];
            batcher.push_keyed(
                Request {
                    id,
                    model: m,
                    tokens,
                    padded_len: 8,
                    cost: 8,
                    submitted: Instant::now(),
                    origin: None,
                    reply: tx,
                },
                m,
                1,
            );
            receivers.push(rx);
        }
    }

    for _ in 0..total_batches {
        let batch = batcher.take_batch();
        assert_eq!(batch.len(), 4, "every measured group is a full bucket");
        let model = batch[0].model;
        assert!(batch.iter().all(|r| r.model == model), "dispatch group crossed models");
        let responses = pool.dispatch(batch);
        assert!(responses.iter().all(|r| r.error.is_none()));
    }
    drop(receivers); // the unserved backlog is measurement headroom

    let total_w: u64 = weights.iter().sum();
    let total: u64 =
        (0..3).map(|m| metrics.model(m).served_padded_tokens.load(Ordering::Relaxed)).sum();
    assert_eq!(total, total_batches as u64 * 32, "every group charged 4 x 8 padded tokens");
    for (m, &w) in weights.iter().enumerate() {
        let served = metrics.model(m).served_padded_tokens.load(Ordering::Relaxed);
        let share = served as f64 / total as f64;
        let target = w as f64 / total_w as f64;
        assert!(
            (share - target).abs() <= 0.1 * target,
            "{}: served share {share:.3} vs weight share {target:.3} (served {served} of {total})",
            names[m]
        );
    }
    // the metrics report carries the share next to the weight
    let report = metrics.report();
    assert!(report.contains("model roberta_base"), "{report}");
    assert!(report.contains("share="), "{report}");
}

#[test]
fn heavy_model_is_not_starved_by_a_flood_of_cheap_traffic() {
    // A tiny-model flood cannot push roberta_large-class work past its
    // deadline: the expired heavy request dispatches before the full
    // tiny bucket.  Mock engines keep this instant; the priority logic
    // under test is the batcher's (the same one the router drives).
    struct Instant0;
    impl EngineReplica for Instant0 {
        fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
            Ok(Prediction {
                label: tokens.len() % 2,
                logits: vec![0, 1],
                accel_cycles: 1,
                accel_ms: 0.001,
            })
        }
        fn seq_len(&self) -> usize {
            256
        }
        fn min_seq_len(&self) -> usize {
            1
        }
    }
    let mut reg = ModelRegistry::new();
    reg.register_group("tiny", vec![Arc::new(Instant0) as Arc<dyn EngineReplica>], 4).unwrap();
    reg.register_group("large", vec![Arc::new(Instant0) as Arc<dyn EngineReplica>], 1).unwrap();
    let metrics = Arc::new(Metrics::new());
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::ZERO, bucket_width: 8 };
    let pool = ReplicaPool::new_multi(reg.into_groups(), Arc::clone(&metrics));
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    batcher.set_model_weights(&[4, 1]);

    let mk = |model: usize, len: usize| {
        let (tx, rx) = channel();
        let req = Request {
            id: 0,
            model,
            tokens: vec![0; len],
            padded_len: len.div_ceil(8) * 8,
            cost: (len.div_ceil(8) * 8) as u64,
            submitted: Instant::now(),
            origin: None,
            reply: tx,
        };
        (req, rx)
    };
    let (heavy, _rx_heavy) = mk(1, 200);
    batcher.push_keyed(heavy, 1, 200);
    std::thread::sleep(Duration::from_millis(2));
    let mut rxs = Vec::new();
    for _ in 0..8 {
        let (req, rx) = mk(0, 3);
        batcher.push_keyed(req, 0, 3);
        rxs.push(rx);
    }
    // first dispatch: the expired heavy request, not the full flood
    let first = batcher.take_batch();
    assert_eq!(first.len(), 1);
    assert_eq!(first[0].model, 1, "expired heavy request outranks the tiny flood");
    let responses = pool.dispatch(first);
    assert_eq!(responses[0].model, "large");
    // the flood still drains afterwards
    let mut tiny_served = 0;
    while tiny_served < 8 {
        let batch = batcher.take_batch();
        assert!(batch.iter().all(|r| r.model == 0));
        tiny_served += pool.dispatch(batch).len();
    }
    assert_eq!(metrics.model(1).completed.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.model(0).completed.load(Ordering::Relaxed), 8);
}
