//! Property suite for the packed INT4 kernel tier (DESIGN.md §14):
//! every packed variant must match the unpacked golden reference
//! (`i_matmul_int4_ref`, nibbles expanded through the INT8 kernel) bit
//! for bit — randomized shapes, odd contraction depths, zero tails,
//! fused epilogues, and the row-tiled / auto-dispatching parallel entry
//! points.  The INT32 accumulator-width guard is exercised under
//! `debug_assertions`.

use swifttron::quant::{
    bias_int4, i_matmul_int4, i_matmul_int4_epilogue, i_matmul_int4_epilogue_par,
    i_matmul_int4_epilogue_tiled, i_matmul_int4_par, i_matmul_int4_ref, i_matmul_int4_ref_epilogue,
    i_matmul_int4_tiled, int4_from_int8, int4_readout_dyadic, pack_int4, unpack_int4, Dyadic,
    Epilogue, INT4_SHIFT,
};
use swifttron::util::rng::Rng;

/// Random nibble-range weights `(k, n)`, packed.
fn random_packed(rng: &mut Rng, k: usize, n: usize) -> Vec<u8> {
    let w4: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-8, 7) as i32).collect();
    pack_int4(&w4, k, n)
}

/// Random INT8-range activations `(m, k)`, with occasional all-zero
/// rows and zero runs so the kernel's zero-skip fast path is covered.
fn random_activations(rng: &mut Rng, m: usize, k: usize) -> Vec<i32> {
    let mut x: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
    for i in 0..m {
        match rng.below(4) {
            0 => x[i * k..(i + 1) * k].fill(0),
            1 => {
                let run = rng.below(k as u64 + 1) as usize;
                x[i * k..i * k + run].fill(0);
            }
            _ => {}
        }
    }
    x
}

#[test]
fn packed_matches_unpacked_reference_on_randomized_shapes() {
    let mut rng = Rng::new(0x14_4E15);
    for trial in 0..200 {
        let m = 1 + rng.below(9) as usize;
        let k = 1 + rng.below(33) as usize; // odd and even depths
        let n = 1 + rng.below(17) as usize;
        let x = random_activations(&mut rng, m, k);
        let packed = random_packed(&mut rng, k, n);
        let bias: Option<Vec<i32>> = rng
            .bool()
            .then(|| (0..n).map(|_| rng.range_i64(-1000, 1000) as i32).collect());
        let mut got = vec![0i32; m * n];
        let mut want = vec![1i32; m * n]; // different init: outputs must be fully written
        i_matmul_int4(&x, &packed, bias.as_deref(), m, k, n, &mut got);
        i_matmul_int4_ref(&x, &packed, bias.as_deref(), m, k, n, &mut want);
        assert_eq!(got, want, "trial {trial}: m={m} k={k} n={n} bias={}", bias.is_some());
    }
}

#[test]
fn odd_k_tail_high_nibble_is_zero_and_harmless() {
    // An odd contraction depth leaves the final packed byte's high
    // nibble zero; the kernel's zero stand-in activation for that lane
    // must not perturb the result whatever the (ignored) activation
    // memory beyond the row would have held.
    let mut rng = Rng::new(0x0DD);
    for &k in &[1usize, 3, 5, 7, 31] {
        let n = 6;
        let w4: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-8, 7) as i32).collect();
        let packed = pack_int4(&w4, k, n);
        // the tail byte row holds only the low nibble
        for j in 0..n {
            let tail = packed[(k / 2) * n + j];
            assert_eq!(tail >> 4, 0, "k={k}: odd-k tail high nibble must pack as zero");
        }
        assert_eq!(unpack_int4(&packed, k, n), w4, "k={k}: round trip");
        let x: Vec<i32> = (0..k).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let mut got = vec![0i32; n];
        let mut want = vec![0i32; n];
        i_matmul_int4(&x, &packed, None, 1, k, n, &mut got);
        i_matmul_int4_ref(&x, &packed, None, 1, k, n, &mut want);
        assert_eq!(got, want, "k={k}");
    }
}

#[test]
fn fused_epilogues_match_reference_and_unfused_apply() {
    let mut rng = Rng::new(0x0E91);
    for trial in 0..100 {
        let m = 1 + rng.below(8) as usize;
        let k = 1 + rng.below(25) as usize;
        let n = 1 + rng.below(12) as usize;
        let x = random_activations(&mut rng, m, k);
        let packed = random_packed(&mut rng, k, n);
        let bias: Vec<i32> = (0..n).map(|_| rng.range_i64(-500, 500) as i32).collect();
        // the INT4 requantize path: the 2^4-scaled dyadic restores the
        // INT8 accumulator scale
        let dy = int4_readout_dyadic(Dyadic::approx16(0.0001 + rng.f64() * 0.1));
        for epi in [Epilogue::Requant(dy), Epilogue::Rescale(dy)] {
            let mut fused = vec![0i32; m * n];
            let mut reference = vec![0i32; m * n];
            let mut unfused = vec![0i32; m * n];
            i_matmul_int4_epilogue(&x, &packed, Some(&bias), m, k, n, epi, &mut fused);
            i_matmul_int4_ref_epilogue(&x, &packed, Some(&bias), m, k, n, epi, &mut reference);
            i_matmul_int4(&x, &packed, Some(&bias), m, k, n, &mut unfused);
            epi.apply(&mut unfused);
            assert_eq!(fused, reference, "trial {trial}: fused packed vs fused reference");
            assert_eq!(fused, unfused, "trial {trial}: fusion must be numerically invisible");
        }
    }
}

#[test]
fn tiled_and_par_variants_are_bit_exact_with_serial() {
    let mut rng = Rng::new(0x7115);
    let (m, k, n) = (37, 29, 19); // awkward shapes: uneven tiles, odd k
    let x = random_activations(&mut rng, m, k);
    let packed = random_packed(&mut rng, k, n);
    let bias: Vec<i32> = (0..n).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
    let mut serial = vec![0i32; m * n];
    i_matmul_int4(&x, &packed, Some(&bias), m, k, n, &mut serial);
    for threads in [1, 2, 3, 8, 64] {
        let mut tiled = vec![0i32; m * n];
        i_matmul_int4_tiled(threads, &x, &packed, Some(&bias), m, k, n, &mut tiled);
        assert_eq!(tiled, serial, "threads={threads}");
    }
    let mut par = vec![0i32; m * n];
    i_matmul_int4_par(&x, &packed, Some(&bias), m, k, n, &mut par);
    assert_eq!(par, serial);
    let epi = Epilogue::Requant(int4_readout_dyadic(Dyadic::approx16(0.003)));
    let mut serial_epi = serial.clone();
    epi.apply(&mut serial_epi);
    for threads in [2, 5] {
        let mut tiled = vec![0i32; m * n];
        i_matmul_int4_epilogue_tiled(threads, &x, &packed, Some(&bias), m, k, n, epi, &mut tiled);
        assert_eq!(tiled, serial_epi, "threads={threads}");
    }
    let mut par_epi = vec![0i32; m * n];
    i_matmul_int4_epilogue_par(&x, &packed, Some(&bias), m, k, n, epi, &mut par_epi);
    assert_eq!(par_epi, serial_epi);
}

#[test]
fn weight_and_bias_quantization_follow_the_int8_grid() {
    // w8 = 16*w4 on-grid, round-half-up between cells, rails clamp
    assert_eq!(
        int4_from_int8(&[0, 15, 16, 24, -24, -25, 127, -128]),
        vec![0, 1, 1, 2, -1, -2, 7, -8]
    );
    // biases divide by 16 with the same rounding but keep full range
    assert_eq!(bias_int4(&[0, 16, -16, 1000, -1000]), vec![0, 1, -1, 63, -62]);
    // every quantized weight packs (nibble range is guaranteed)
    let mut rng = Rng::new(0x9);
    let w8: Vec<i32> = (0..64).map(|_| rng.range_i64(-127, 127) as i32).collect();
    let w4 = int4_from_int8(&w8);
    assert!(w4.iter().all(|&v| (-8..=7).contains(&v)));
    let packed = pack_int4(&w4, 8, 8);
    assert_eq!(unpack_int4(&packed, 8, 8), w4);
}

#[test]
fn readout_dyadic_scaling_is_exact_for_representable_shifts() {
    // dy4 = dy << 4 when dy has headroom in its shift; the fused
    // requantize of a 16x-smaller accumulator is then bit-exact with
    // the INT8 path (the identity the whole INT4 tier rests on)
    let dy = Dyadic::approx16(0.0043);
    let dy4 = int4_readout_dyadic(dy);
    assert_eq!(dy4.b, dy.b);
    assert_eq!(dy4.c, dy.c - INT4_SHIFT);
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "contraction too deep")]
fn accumulator_width_guard_trips_on_unsafe_depth_in_debug() {
    // k beyond 2^31 / (128*8) could overflow the INT32 accumulator at
    // the rails; the packed kernel refuses it under debug_assertions
    let k = (i32::MAX as usize) / (128 * 8) + 1;
    let x = vec![0i32; k];
    let w4 = vec![0i32; k];
    let packed = pack_int4(&w4, k, 1);
    let mut out = vec![0i32; 1];
    i_matmul_int4(&x, &packed, None, 1, k, 1, &mut out);
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "INT8 range")]
fn activation_range_guard_trips_in_debug() {
    let packed = pack_int4(&[1, 1], 2, 1);
    let mut out = vec![0i32; 1];
    i_matmul_int4(&[200, -200], &packed, None, 1, 2, 1, &mut out);
}
