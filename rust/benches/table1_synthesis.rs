//! Table I — synthesis summary of the complete SwiftTron architecture
//! (paper: 143 MHz, 65 nm, 33.64 W, 273.0 mm^2 at d=768, k=12, m=256,
//! d_ff=3072) — plus the **design-space sweep leg** (DESIGN.md §12):
//! `synthesis::design_space::explore` over every geometry preset,
//! reporting each space's Pareto front and budget-constrained
//! recommendation, merged under `costmodel.design_space` in
//! `BENCH_serving.json`.
//!
//! Determinism gate: the sweep is closed-form (analytical CostModel ×
//! gate-level synthesis model), so its smoke subset must be
//! *byte-identical* run to run.  `--smoke` compares the subset against
//! the committed `BENCH_costmodel_smoke.json` snapshot and fails on any
//! drift; `--update` (or a missing/uninitialized snapshot) rewrites the
//! baseline instead — commit the file after an intentional model
//! change.

use std::collections::BTreeMap;
use swifttron::model::Geometry;
use swifttron::sim::HwConfig;
use swifttron::synthesis::{explore, synthesis_report, Budget, DesignPoint, DesignSpace};
use swifttron::util::bench::{merge_bench_json, Table};
use swifttron::util::json::{obj, Json};

const SNAPSHOT_PATH: &str = "BENCH_costmodel_smoke.json";
const SNAPSHOT_SCHEMA: &str = "swifttron-costmodel-smoke-v1";
/// The deterministic subset the snapshot pins: small grids, fast even
/// in CI, but enough to catch any drift in the cost or synthesis model.
const SMOKE_PRESETS: [&str; 2] = ["tiny", "small"];

fn hw_json(hw: &HwConfig) -> Json {
    obj([
        ("array_rows", hw.array_rows.into()),
        ("array_cols", hw.array_cols.into()),
        ("parallel_heads", hw.parallel_heads.into()),
        ("softmax_units", hw.softmax_units.into()),
        ("layernorm_lanes", hw.layernorm_lanes.into()),
        ("clock_ns", hw.clock_ns.into()),
    ])
}

fn point_json(p: &DesignPoint) -> Json {
    obj([
        ("hw", hw_json(&p.hw)),
        ("latency_ms", p.latency_ms.into()),
        ("area_mm2", p.area_mm2.into()),
        ("power_w", p.power_w.into()),
        ("critical_path_ns", p.critical_path_ns.into()),
    ])
}

fn space_json(ds: &DesignSpace) -> Json {
    obj([
        ("points", ds.points.len().into()),
        ("skipped", ds.skipped.into()),
        ("pareto", ds.pareto_front().len().into()),
        (
            "recommended",
            match ds.recommended_point() {
                Some(p) => point_json(p),
                None => Json::Null,
            },
        ),
    ])
}

/// Sweep `presets` and return the per-preset JSON; `quiet` suppresses
/// the table (the snapshot recomputation path — the smoke table was
/// already printed by the main sweep).
fn sweep(presets: &[&str], budget: Budget, quiet: bool) -> BTreeMap<String, Json> {
    let mut table = Table::new(&[
        "preset", "points", "pareto", "recommended", "latency", "area", "power",
    ]);
    let mut out = BTreeMap::new();
    for &name in presets {
        let ds = explore(name, budget).expect("preset resolves");
        let (rec, lat, area, power) = match ds.recommended_point() {
            Some(p) => (
                format!(
                    "{}x{} h={} sm={} @{:.0}ns",
                    p.hw.array_rows,
                    p.hw.array_cols,
                    p.hw.parallel_heads,
                    p.hw.softmax_units,
                    p.hw.clock_ns
                ),
                format!("{:.4}ms", p.latency_ms),
                format!("{:.1}mm^2", p.area_mm2),
                format!("{:.2}W", p.power_w),
            ),
            None => ("<none in budget>".into(), "-".into(), "-".into(), "-".into()),
        };
        table.row(&[
            name.to_string(),
            ds.points.len().to_string(),
            ds.pareto_front().len().to_string(),
            rec,
            lat,
            area,
            power,
        ]);
        out.insert(name.to_string(), space_json(&ds));
    }
    if !quiet {
        table.print(&format!(
            "design-space sweep: recommended HwConfig per preset (budget {:.0} mm^2 / {:.1} W)",
            budget.max_area_mm2, budget.max_power_w
        ));
    }
    out
}

/// The canonical snapshot payload string for the smoke subset.
fn snapshot_payload() -> String {
    let spaces = sweep(&SMOKE_PRESETS, Budget::default(), true);
    let json = Json::Obj(BTreeMap::from([
        ("schema".to_string(), SNAPSHOT_SCHEMA.into()),
        ("presets".to_string(), Json::Obj(spaces)),
    ]));
    format!("{json}\n")
}

/// Compare (or initialize/update) the committed smoke snapshot.
/// Returns false when the comparison failed.
fn check_snapshot(update: bool) -> bool {
    let payload = snapshot_payload();
    let on_disk = std::fs::read_to_string(SNAPSHOT_PATH).ok();
    let initialized = on_disk
        .as_deref()
        .and_then(|s| Json::parse(s.trim()).ok())
        .is_some_and(|j| {
            j.get("presets").is_some()
                && j.get("schema").and_then(|s| s.as_str()) == Some(SNAPSHOT_SCHEMA)
        });
    if update || !initialized {
        match std::fs::write(SNAPSHOT_PATH, &payload) {
            Ok(()) => println!(
                "\n{} {SNAPSHOT_PATH} — commit it to pin the baseline",
                if update { "updated" } else { "initialized" }
            ),
            Err(e) => eprintln!("\nfailed to write {SNAPSHOT_PATH}: {e}"),
        }
        return true;
    }
    if on_disk.as_deref() == Some(payload.as_str()) {
        println!("\nsmoke snapshot matches {SNAPSHOT_PATH} (deterministic sweep verified)");
        true
    } else {
        eprintln!(
            "\nsmoke snapshot MISMATCH against {SNAPSHOT_PATH}: the closed-form sweep\n\
             changed.  If the cost/synthesis model change is intentional, re-baseline\n\
             with `cargo bench --bench table1_synthesis -- --smoke --update` and commit\n\
             the snapshot; otherwise this is a determinism regression.\n\
             expected (committed):\n{}\n\
             got (this run):\n{}",
            on_disk.as_deref().unwrap_or("<unreadable>").trim_end(),
            payload.trim_end()
        );
        false
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let update = args.iter().any(|a| a == "--update");

    // --- Table I (the original leg) --------------------------------
    let cfg = HwConfig::paper();
    let geo = Geometry::preset("roberta_base").unwrap();
    let r = synthesis_report(&cfg, &geo);

    let mut t = Table::new(&["metric", "paper", "this model"]);
    t.row(&["Clock Frequency".into(), "143 MHz".into(), format!("{:.0} MHz", r.clock_mhz)]);
    t.row(&["Technology Node".into(), "65 nm".into(), r.tech_node.to_string()]);
    t.row(&["Power Consumption".into(), "33.64 W".into(), format!("{:.2} W", r.power_w)]);
    t.row(&["Area".into(), "273.0 mm^2".into(), format!("{:.1} mm^2", r.area_mm2)]);
    t.row(&[
        "Critical path".into(),
        "<= 7 ns (meets timing)".into(),
        format!("{:.2} ns", r.critical_path_ns),
    ]);
    t.print("Table I — SwiftTron synthesis summary");
    println!(
        "\nshape check: same order of magnitude for area and power; timing met at 7 ns: {}",
        r.critical_path_ns <= 7.0
    );

    // --- design-space sweep leg (DESIGN.md §12) --------------------
    println!();
    let presets: Vec<&str> =
        if smoke { SMOKE_PRESETS.to_vec() } else { Geometry::PRESET_NAMES.to_vec() };
    let spaces = sweep(&presets, Budget::default(), false);
    println!(
        "\neach preset's recommendation is the fastest clock-feasible candidate\n\
         inside the default area/power budget; `swifttron tune` prints the\n\
         full per-preset summary, including the Pareto front."
    );
    let path = "BENCH_serving.json";
    let legs = [("costmodel", obj([("design_space", Json::Obj(spaces))]))];
    match merge_bench_json(path, legs) {
        Ok(()) => println!("\nwrote {path} (costmodel.design_space)"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    // --- determinism gate: the committed smoke snapshot ------------
    if !check_snapshot(update) {
        std::process::exit(1);
    }
}
