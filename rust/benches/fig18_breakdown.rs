//! Fig. 18 — area and power breakdown per component.
//!
//! Paper (RoBERTa-base config): area MatMul 55%, LayerNorm 25%,
//! Softmax 17%, GELU 3%; power MatMul dominant (~79%), Softmax 14%,
//! LayerNorm 6%, GELU 1%.  Shape targets: same ranking, LN area-heavy /
//! power-light, GELU negligible.

use swifttron::model::Geometry;
use swifttron::sim::HwConfig;
use swifttron::synthesis::synthesis_report;
use swifttron::util::bench::Table;

fn main() {
    let r = synthesis_report(&HwConfig::paper(), &Geometry::preset("roberta_base").unwrap());

    let paper_area = [("MatMul", 55.0), ("LayerNorm", 25.0), ("Softmax", 17.0), ("GELU", 3.0)];
    let paper_power = [("MatMul", 79.0), ("Softmax", 14.0), ("LayerNorm", 6.0), ("GELU", 1.0)];

    let mut t = Table::new(&["component", "paper area %", "model area %", "paper power %", "model power %"]);
    for (name, pa) in paper_area {
        let pp = paper_power.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap();
        t.row(&[
            name.to_string(),
            format!("{pa:.0}%"),
            format!("{:.1}%", r.area_pct.get(name).copied().unwrap_or(0.0)),
            format!("{pp:.0}%"),
            format!("{:.1}%", r.power_pct.get(name).copied().unwrap_or(0.0)),
        ]);
    }
    for extra in ["Requant", "Control"] {
        t.row(&[
            format!("{extra} (not broken out in paper)"),
            "-".into(),
            format!("{:.1}%", r.area_pct.get(extra).copied().unwrap_or(0.0)),
            "-".into(),
            format!("{:.1}%", r.power_pct.get(extra).copied().unwrap_or(0.0)),
        ]);
    }
    t.print("Fig. 18 — area & power breakdown (RoBERTa-base configuration)");

    // shape assertions, printed so regressions are visible in bench logs
    let a = &r.area_pct;
    let p = &r.power_pct;
    println!("\nshape checks:");
    println!("  MatMul largest area:            {}", a["MatMul"] > a["LayerNorm"]);
    println!("  LayerNorm > Softmax area:       {}", a["LayerNorm"] > a["Softmax"]);
    println!("  GELU smallest of the four:      {}", a["GELU"] < a["Softmax"]);
    println!("  LayerNorm area-heavy/power-light: {}", a["LayerNorm"] > p["LayerNorm"]);
    println!("  MatMul power-dominant:          {}", p["MatMul"] > 50.0);
}
