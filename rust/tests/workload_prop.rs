//! Property tests for the workload harness (ISSUE 6): the arrival
//! generators' empirical statistics match their parameters, identical
//! seeds give identical streams, and record → save → load → replay
//! round-trips byte-exactly.
//!
//! Tolerances are sized at roughly 4σ of the relevant estimator so the
//! tests are sharp enough to catch a wrong generator but do not flake:
//! a Poisson count over `n` expected arrivals has σ = √n, an MMPP dwell
//! mean over `k` sojourns has σ = mean/√k.

use std::sync::Arc;
use std::time::Duration;
use swifttron::coordinator::{BatchPolicy, EngineReplica, Metrics, ModelRegistry, Router};
use swifttron::workload::{replay, ArrivalProcess, DelayReplica, RateSpike, Trace};

#[test]
fn poisson_empirical_rate_matches_lambda() {
    let rate = 200.0;
    let horizon = 50.0;
    let arrivals = ArrivalProcess::Poisson { rate }.sample(11, horizon);
    assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "arrivals are sorted");
    assert!(arrivals.iter().all(|&t| (0.0..horizon).contains(&t)));
    let n = arrivals.len() as f64;
    let expect = rate * horizon; // 10_000 ± 100 (1σ)
    assert!((n - expect).abs() < 0.05 * expect, "count {n} vs expected {expect}");
    // exponential gaps: mean 1/λ and squared-CV 1 (the memoryless
    // signature a deterministic or uniform generator would fail)
    let gaps: Vec<f64> = arrivals.windows(2).map(|w| w[1] - w[0]).collect();
    let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
    let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
    let cv2 = var / (mean * mean);
    assert!((mean - 1.0 / rate).abs() < 0.1 / rate, "gap mean {mean}");
    assert!((cv2 - 1.0).abs() < 0.15, "squared CV {cv2} should be ~1 for Poisson");
}

#[test]
fn mmpp_dwell_times_match_the_generator_means() {
    let p = ArrivalProcess::Mmpp2 { rates: [300.0, 20.0], mean_dwell_s: [0.5, 0.125] };
    let (arrivals, dwells) = p.sample_with_dwells(13, 400.0);
    // ~640 completed sojourns per state over the horizon
    let mean_dwell = |state: usize| {
        let v: Vec<f64> =
            dwells.iter().filter(|d| d.state == state).map(|d| d.dwell_s).collect();
        assert!(v.len() > 100, "state {state} visited only {} times", v.len());
        v.iter().sum::<f64>() / v.len() as f64
    };
    assert!((mean_dwell(0) - 0.5).abs() < 0.15 * 0.5, "state-0 dwell {}", mean_dwell(0));
    assert!((mean_dwell(1) - 0.125).abs() < 0.15 * 0.125, "state-1 dwell {}", mean_dwell(1));
    // the two states alternate strictly
    assert!(dwells.windows(2).all(|w| w[0].state != w[1].state));
    // total mass matches the dwell-weighted stationary rate
    let expect = p.mean_rate() * 400.0;
    let n = arrivals.len() as f64;
    assert!((n - expect).abs() < 0.08 * expect, "count {n} vs expected {expect}");
}

#[test]
fn diurnal_ramp_concentrates_arrivals_at_the_peak_phase() {
    let p = ArrivalProcess::Diurnal { base: 20.0, peak: 400.0, period_s: 1.0 };
    let horizon = 40.0; // whole periods, so mean_rate() is exact
    let arrivals = p.sample(17, horizon);
    let expect = p.mean_rate() * horizon;
    let n = arrivals.len() as f64;
    assert!((n - expect).abs() < 0.08 * expect, "count {n} vs expected {expect}");
    // λ averages ~381 req/s over the peak quarter-phase vs ~39 over the
    // trough quarter — a ~10x contrast; 3x is the flake-proof bound
    let phase = |t: f64| t.fract();
    let peak = arrivals.iter().filter(|&&t| (0.375..0.625).contains(&phase(t))).count();
    let trough =
        arrivals.iter().filter(|&&t| !(0.125..0.875).contains(&phase(t))).count();
    assert!(
        peak as f64 > 3.0 * trough as f64,
        "peak quarter {peak} vs trough quarter {trough}"
    );
}

#[test]
fn identical_seeds_give_identical_streams() {
    let processes = [
        ArrivalProcess::Poisson { rate: 120.0 },
        ArrivalProcess::Mmpp2 { rates: [200.0, 10.0], mean_dwell_s: [0.2, 0.1] },
        ArrivalProcess::Diurnal { base: 10.0, peak: 200.0, period_s: 2.0 },
    ];
    for p in &processes {
        let a = p.sample(99, 10.0);
        let b = p.sample(99, 10.0);
        assert_eq!(a, b, "{p:?}: same seed must give the bit-identical stream");
        let c = p.sample(100, 10.0);
        assert_ne!(a, c, "{p:?}: different seeds must diverge");
    }
}

#[test]
fn rate_spike_superposes_the_expected_extra_mass() {
    let p = ArrivalProcess::Poisson { rate: 100.0 };
    let spike = RateSpike { from_s: 1.0, until_s: 2.0, factor: 50.0 };
    let base = p.sample(21, 4.0);
    let spiked = p.sample_spiked(21, 4.0, &spike);
    assert_eq!(spiked, p.sample_spiked(21, 4.0, &spike), "spiked stream is deterministic");
    // extra mass = (factor-1)·λ·window = 4900 ± 70 (1σ)
    let extra = (spiked.len() - base.len()) as f64;
    assert!((extra - 4900.0).abs() < 0.06 * 4900.0, "extra {extra}");
    // the base stream is untouched outside the window
    let outside = |v: &[f64]| -> Vec<f64> {
        v.iter().copied().filter(|&t| !(1.0..2.0).contains(&t)).collect()
    };
    assert_eq!(outside(&spiked), outside(&base));
}

#[test]
fn trace_record_save_load_round_trips_byte_exact() {
    let tenants = [
        ArrivalProcess::Poisson { rate: 150.0 },
        ArrivalProcess::Mmpp2 { rates: [200.0, 10.0], mean_dwell_s: [0.2, 0.1] },
    ];
    let record = || {
        let traces: Vec<Trace> = tenants
            .iter()
            .enumerate()
            .map(|(m, p)| Trace::from_process(p, 31 + m as u64, 3.0, m, (4, 64)))
            .collect();
        Trace::merge(&traces)
    };
    let merged = record();
    assert!(!merged.is_empty());
    assert!(merged.events().windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
    assert!(merged.events().iter().all(|e| (4..=64).contains(&(e.len as usize))));
    assert_eq!(record(), merged, "recording is deterministic in the seeds");

    let path = std::env::temp_dir()
        .join(format!("swifttron_trace_prop_{}.swtrace", std::process::id()));
    merged.save(&path).unwrap();
    let on_disk = std::fs::read(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, merged, "load(save(x)) == x");
    assert_eq!(on_disk, merged.to_bytes(), "the file is exactly the serialization");
    assert_eq!(loaded.to_bytes(), on_disk, "save(load(bytes)) == bytes");
}

#[test]
fn replay_records_the_exact_stream_it_submits() {
    let mut reg = ModelRegistry::new();
    reg.register_group(
        "m",
        vec![Arc::new(DelayReplica::from_ms(0)) as Arc<dyn EngineReplica>],
        1,
    )
    .unwrap();
    let metrics = Arc::new(Metrics::new());
    let policy =
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200), bucket_width: 0 };
    let router = Router::start_multi(reg.into_groups(), policy, Arc::clone(&metrics));
    let trace =
        Trace::from_process(&ArrivalProcess::Poisson { rate: 400.0 }, 41, 0.25, 0, (1, 16));
    let summary = replay(&router, &trace, 0.5, Duration::from_secs(20));
    // every recorded reply arrives before shutdown: open-loop but lossless
    let sent = summary.sent;
    router.shutdown();
    assert_eq!(sent, trace.len());
    assert_eq!(summary.lost, 0, "no reply went missing");
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.completed, trace.len());
    assert_eq!(
        summary.recorded, trace,
        "the driver records bit-identically what it replays"
    );
    assert_eq!(
        metrics.model(0).completed.load(std::sync::atomic::Ordering::SeqCst),
        trace.len() as u64
    );
}
