//! Table III: qualitative feature comparison with the related works.
//!
//! The paper's claim is that SwiftTron is the only design satisfying all
//! four requirements simultaneously; this module encodes the table and a
//! checker for that claim so the bench regenerates it verbatim.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HwTarget {
    Asic(&'static str),
    Fpga(&'static str),
    Gpu(&'static str),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NonlinearImpl {
    IntegerApprox,
    Lut,
    Fft,
    Fp16, // FP16 and/or FP32
    Fp32,
    NotApplicable,
}

#[derive(Clone, Debug)]
pub struct RelatedWork {
    pub name: &'static str,
    pub hw: HwTarget,
    pub bitwidth: &'static str,
    /// bit-width counts as "efficient" (INT8 or narrower)
    pub bitwidth_ok: bool,
    pub complete_architecture: bool,
    pub nonlinear: NonlinearImpl,
}

impl RelatedWork {
    /// Specialized hardware (not a GPU deployment).
    pub fn hw_ok(&self) -> bool {
        !matches!(self.hw, HwTarget::Gpu(_))
    }

    /// Efficient nonlinear functions = integer approximations.
    pub fn nonlinear_ok(&self) -> bool {
        self.nonlinear == NonlinearImpl::IntegerApprox
    }

    pub fn all_features(&self) -> bool {
        self.hw_ok() && self.bitwidth_ok && self.complete_architecture && self.nonlinear_ok()
    }
}

/// The rows of the paper's Table III, in order.
pub fn comparison_table() -> Vec<RelatedWork> {
    use HwTarget::*;
    use NonlinearImpl::*;
    vec![
        RelatedWork { name: "OPTIMUS [2]", hw: Asic("28 nm"), bitwidth: "INT16", bitwidth_ok: false, complete_architecture: false, nonlinear: NotApplicable },
        RelatedWork { name: "A^3 [3]", hw: Asic("40 nm"), bitwidth: "INT8", bitwidth_ok: true, complete_architecture: false, nonlinear: IntegerApprox },
        RelatedWork { name: "FTRANS [27]", hw: Fpga("Xilinx"), bitwidth: "INT16", bitwidth_ok: false, complete_architecture: true, nonlinear: Fft },
        RelatedWork { name: "Lu et al. [20]", hw: Fpga("Xilinx"), bitwidth: "INT8", bitwidth_ok: true, complete_architecture: false, nonlinear: IntegerApprox },
        RelatedWork { name: "EFA-Trans [25]", hw: Fpga("Xilinx"), bitwidth: "INT8", bitwidth_ok: true, complete_architecture: true, nonlinear: Lut },
        RelatedWork { name: "FQ-BERT [26]", hw: Fpga("Xilinx"), bitwidth: "INT8", bitwidth_ok: true, complete_architecture: true, nonlinear: Lut },
        RelatedWork { name: "Lin et al. [4]", hw: Gpu("TITAN V"), bitwidth: "INT8", bitwidth_ok: true, complete_architecture: true, nonlinear: Fp32 },
        RelatedWork { name: "I-BERT [7]", hw: Gpu("Tesla T4"), bitwidth: "INT8", bitwidth_ok: true, complete_architecture: true, nonlinear: IntegerApprox },
        RelatedWork { name: "I-ViT [17]", hw: Gpu("RTX 2080 Ti"), bitwidth: "INT8", bitwidth_ok: true, complete_architecture: true, nonlinear: IntegerApprox },
        RelatedWork { name: "Transformer Engine [5]", hw: Asic("4 nm (H100)"), bitwidth: "FP8", bitwidth_ok: true, complete_architecture: true, nonlinear: Fp16 },
        RelatedWork { name: "SwiftTron (ours)", hw: Asic("65 nm"), bitwidth: "INT8", bitwidth_ok: true, complete_architecture: true, nonlinear: IntegerApprox },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn only_swifttron_has_all_features() {
        let rows = comparison_table();
        let winners: Vec<&str> =
            rows.iter().filter(|r| r.all_features()).map(|r| r.name).collect();
        assert_eq!(winners, vec!["SwiftTron (ours)"]);
    }

    #[test]
    fn every_related_work_misses_something() {
        for r in comparison_table() {
            if r.name != "SwiftTron (ours)" {
                assert!(!r.all_features(), "{} should miss a feature", r.name);
            }
        }
    }

    #[test]
    fn table_has_eleven_rows_like_the_paper() {
        assert_eq!(comparison_table().len(), 11);
    }
}
