//! Full encoder schedule: the paper's control flow (Fig. 16) over one
//! layer — MHSA FSM, LayerNorm FSM, FFN FSM, LayerNorm FSM — repeated
//! per layer, with a handshake trace and a per-block cycle breakdown.
//!
//! Head-level dataflow (Figs. 8-10): the Q/K/V/output projections and the
//! FFN matmuls run on the central R x C MAC array; each head unit owns
//! (m x dh)-shaped attention MatMuls (Q.K^T and P.V) plus Scale, Softmax
//! and Requantization operators.  `parallel_heads` head units work
//! concurrently; extra heads serialize in waves.

use super::control::{Fsm, FsmKind, Trace};
use super::units;
use super::HwConfig;
use crate::model::Geometry;
use std::collections::BTreeMap;

/// Cycle breakdown of one simulated inference.
#[derive(Clone, Debug, Default)]
pub struct LatencyReport {
    /// total cycles from first Start to last Done
    pub total_cycles: u64,
    /// busy cycles per component class (feeds the power duty model)
    pub per_block: BTreeMap<&'static str, u64>,
    pub trace: Trace,
}

impl LatencyReport {
    pub fn ms(&self, cfg: &HwConfig) -> f64 {
        cfg.cycles_to_ms(self.total_cycles)
    }
}

/// Simulate one encoder layer at full length `geo.m`; see
/// [`simulate_layer_m`] for the sequence-shaped variant and the
/// `sqrt_iters` layout (2·m entries: ln1 rows then ln2 rows).
pub fn simulate_layer(
    cfg: &HwConfig,
    geo: &Geometry,
    start_cycle: u64,
    trace: &mut Trace,
    blocks: &mut BTreeMap<&'static str, u64>,
    sqrt_iters: Option<&[u32]>,
) -> u64 {
    simulate_layer_m(cfg, geo, geo.m, start_cycle, trace, blocks, sqrt_iters)
}

/// Simulate one encoder layer over `m_eff <= geo.m` live rows starting
/// at `start_cycle`; returns the completion cycle and accumulates into
/// the trace + per-block map (split borrows of [`LatencyReport`]'s
/// fields).  Every block's cycle count is computed from the actual
/// `m_eff`, not the padded geometry maximum — latency and energy shape
/// to the request, as the hardware loads the MAC array per sentence.
///
/// `sqrt_iters`, when given, must hold `2 * m_eff` data-dependent sqrt
/// iteration counts in the functional model's layout: the ln1 rows
/// first, then the ln2 rows ([`crate::sim::functional::LayerOutput`]).
/// The two LayerNorm FSMs consume their own halves, so data-dependent
/// timing differs between them when the data does.  `None` charges the
/// worst case (paper footnote 3).
pub fn simulate_layer_m(
    cfg: &HwConfig,
    geo: &Geometry,
    m_eff: usize,
    start_cycle: u64,
    trace: &mut Trace,
    blocks: &mut BTreeMap<&'static str, u64>,
    sqrt_iters: Option<&[u32]>,
) -> u64 {
    fn add(blocks: &mut BTreeMap<&'static str, u64>, k: &'static str, v: u64) {
        *blocks.entry(k).or_insert(0) += v;
    }
    let (d, dff, dh) = (geo.d, geo.d_ff, geo.dh());
    let m = m_eff;
    assert!(m >= 1 && m <= geo.m, "m_eff {m} outside 1..={}", geo.m);
    // An empty slice makes `units::layernorm_cycles` fall back to the
    // worst-case count per row — identical to the old padded default
    // without allocating one.
    let (ln1_iters, ln2_iters): (&[u32], &[u32]) = match sqrt_iters {
        Some(it) => {
            assert_eq!(
                it.len(),
                2 * m,
                "sqrt_iters must hold 2*m_eff entries (ln1 rows then ln2 rows)"
            );
            it.split_at(m)
        }
        None => (&[], &[]),
    };

    // ---- MHSA FSM ----
    let mhsa_done = {
        let mut fsm = Fsm::new(FsmKind::Mhsa, trace, start_cycle);
        // Q, K, V projections on the central array (requant overlapped).
        // Weight-stationary: the feed phase streams packed weight
        // panels, so `weight_bits` shapes it (DESIGN.md §14).
        let qkv = 3 * units::weight_matmul_cycles(cfg, m, d, d) + units::requant_cycles(cfg);
        fsm.run_block("qkv_proj", qkv);
        add(blocks, "matmul", 3 * units::weight_matmul_cycles(cfg, m, d, d));
        add(blocks, "requant", units::requant_cycles(cfg));

        // Attention heads in waves of `parallel_heads` (Fig. 9).
        let waves = geo.heads.div_ceil(cfg.parallel_heads) as u64;
        // per head (Fig. 10): Q.K^T -> Scale -> Softmax -> Req -> P.V
        let head_cfg = HwConfig { array_rows: m, array_cols: dh, ..*cfg };
        let qkt = units::matmul_cycles(&head_cfg, m, dh, m);
        let softmax = units::softmax_cycles(cfg, m, m);
        let pv = units::matmul_cycles(&head_cfg, m, m, dh);
        let per_head = qkt + softmax + pv + 2 * units::requant_cycles(cfg);
        fsm.run_block("attention_heads", waves * per_head);
        add(blocks, "matmul", waves * (qkt + pv) * geo.heads.min(cfg.parallel_heads) as u64);
        add(blocks, "softmax", waves * softmax * geo.heads.min(cfg.parallel_heads) as u64);
        add(blocks, "requant", waves * 2 * units::requant_cycles(cfg));

        // Output projection (the extra MatMul of Fig. 9) + residual align.
        let proj = units::weight_matmul_cycles(cfg, m, d, d) + units::residual_cycles(cfg);
        fsm.run_block("out_proj", proj);
        add(blocks, "matmul", units::weight_matmul_cycles(cfg, m, d, d));
        add(blocks, "residual", units::residual_cycles(cfg));
        fsm.now
    };

    // ---- LayerNorm FSM (post-MHSA): consumes the ln1 half ----
    let ln1_done = {
        let mut fsm = Fsm::new(FsmKind::LayerNorm, trace, 0);
        fsm.join(mhsa_done);
        let ln = units::layernorm_cycles(cfg, m, d, ln1_iters) + units::requant_cycles(cfg);
        fsm.run_block("layernorm1", ln);
        add(blocks, "layernorm", units::layernorm_cycles(cfg, m, d, ln1_iters));
        add(blocks, "requant", units::requant_cycles(cfg));
        fsm.now
    };

    // ---- FFN FSM ----
    let ffn_done = {
        let mut fsm = Fsm::new(FsmKind::Ffn, trace, 0);
        fsm.join(ln1_done);
        let mm1 = units::weight_matmul_cycles(cfg, m, d, dff);
        let gelu = units::gelu_cycles(cfg) + units::requant_cycles(cfg);
        let mm2 = units::weight_matmul_cycles(cfg, m, dff, d);
        fsm.run_block("ffn_mm1", mm1);
        fsm.run_block("gelu", gelu);
        fsm.run_block("ffn_mm2", mm2 + units::residual_cycles(cfg));
        add(blocks, "matmul", mm1 + mm2);
        add(blocks, "gelu", units::gelu_cycles(cfg));
        add(blocks, "requant", units::requant_cycles(cfg));
        add(blocks, "residual", units::residual_cycles(cfg));
        fsm.now
    };

    // ---- LayerNorm FSM (post-FFN): consumes the ln2 half ----
    let mut fsm = Fsm::new(FsmKind::LayerNorm, trace, 0);
    fsm.join(ffn_done);
    let ln = units::layernorm_cycles(cfg, m, d, ln2_iters) + units::requant_cycles(cfg);
    fsm.run_block("layernorm2", ln);
    add(blocks, "layernorm", units::layernorm_cycles(cfg, m, d, ln2_iters));
    add(blocks, "requant", units::requant_cycles(cfg));
    fsm.now
}

/// Simulate the full encoder stack of `geo` at full length `geo.m`
/// (worst-case sqrt timing).
pub fn simulate_encoder(cfg: &HwConfig, geo: &Geometry) -> LatencyReport {
    simulate_encoder_m(cfg, geo, geo.m, None)
}

/// Simulate the full encoder stack over `m_eff <= geo.m` live rows.
///
/// `sqrt_iters`, when given, is the functional model's whole-stack
/// layout ([`crate::sim::functional::encoder_forward_ws`]): `2 * m_eff`
/// entries per layer (ln1 rows then ln2 rows), layer by layer — i.e.
/// `2 * m_eff * geo.layers` total.  Identical to [`simulate_encoder`]
/// when `m_eff == geo.m` and `sqrt_iters` is `None`.
pub fn simulate_encoder_m(
    cfg: &HwConfig,
    geo: &Geometry,
    m_eff: usize,
    sqrt_iters: Option<&[u32]>,
) -> LatencyReport {
    if let Some(it) = sqrt_iters {
        assert_eq!(
            it.len(),
            2 * m_eff * geo.layers,
            "sqrt_iters must hold 2*m_eff entries per layer"
        );
    }
    let mut report = LatencyReport::default();
    let mut t = 0;
    for l in 0..geo.layers {
        let layer_iters = sqrt_iters.map(|it| &it[l * 2 * m_eff..(l + 1) * 2 * m_eff]);
        t = simulate_layer_m(
            cfg,
            geo,
            m_eff,
            t,
            &mut report.trace,
            &mut report.per_block,
            layer_iters,
        );
    }
    report.total_cycles = t;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_well_formed() {
        let r = simulate_encoder(&HwConfig::paper(), &Geometry::preset("roberta_base").unwrap());
        r.trace.check_well_formed().unwrap();
    }

    #[test]
    fn roberta_base_latency_in_paper_band() {
        // Paper Table II: 1.83 ms.  Shape target: same order, within 2x.
        let cfg = HwConfig::paper();
        let r = simulate_encoder(&cfg, &Geometry::preset("roberta_base").unwrap());
        let ms = r.ms(&cfg);
        assert!((0.9..=3.7).contains(&ms), "latency {ms} ms");
    }

    #[test]
    fn model_ranking_matches_table2() {
        // deit_s < roberta_base < roberta_large (Table II ordering)
        let cfg = HwConfig::paper();
        let base = simulate_encoder(&cfg, &Geometry::preset("roberta_base").unwrap());
        let large = simulate_encoder(&cfg, &Geometry::preset("roberta_large").unwrap());
        let deit = simulate_encoder(&cfg, &Geometry::preset("deit_s").unwrap());
        assert!(deit.total_cycles < base.total_cycles);
        assert!(base.total_cycles < large.total_cycles);
    }

    #[test]
    fn layers_scale_linearly() {
        let cfg = HwConfig::paper();
        let mut g = Geometry::preset("roberta_base").unwrap();
        let r12 = simulate_encoder(&cfg, &g);
        g.layers = 6;
        let r6 = simulate_encoder(&cfg, &g);
        assert_eq!(r12.total_cycles, 2 * r6.total_cycles);
    }

    #[test]
    fn matmul_dominates_busy_cycles() {
        let cfg = HwConfig::paper();
        let r = simulate_encoder(&cfg, &Geometry::preset("roberta_base").unwrap());
        let mm = r.per_block["matmul"];
        let total: u64 = r.per_block.values().sum();
        assert!(mm * 2 > total, "matmul {mm} of {total}");
    }

    #[test]
    fn smaller_array_is_slower() {
        let geo = Geometry::preset("roberta_base").unwrap();
        let paper = simulate_encoder(&HwConfig::paper(), &geo);
        let edge = simulate_encoder(&HwConfig::edge(), &geo);
        assert!(edge.total_cycles > paper.total_cycles);
    }

    #[test]
    fn m_eff_matches_truncated_geometry() {
        // simulate_encoder_m over a big geometry's live prefix must cost
        // exactly what a geometry truncated to m = m_eff costs: the
        // variable-length path never charges the padded maximum.
        let cfg = HwConfig::paper();
        let geo = Geometry::preset("roberta_base").unwrap();
        for m_eff in [1usize, 17, geo.m / 4, geo.m / 2, geo.m] {
            let var = simulate_encoder_m(&cfg, &geo, m_eff, None);
            let trunc = simulate_encoder(&cfg, &Geometry { m: m_eff, ..geo });
            assert_eq!(var.total_cycles, trunc.total_cycles, "m_eff={m_eff}");
            assert_eq!(var.per_block, trunc.per_block, "m_eff={m_eff}");
        }
    }

    #[test]
    fn short_sequences_cost_fewer_cycles() {
        // The sequence-shaped blocks (attention heads, softmax waves,
        // LayerNorm rows) scale with m_eff; the central-array feed
        // cycles are row-occupancy-independent below the array height,
        // so the total shrinks strictly but sub-linearly.
        let cfg = HwConfig::paper();
        let geo = Geometry::preset("roberta_base").unwrap();
        let quarter = simulate_encoder_m(&cfg, &geo, geo.m / 4, None);
        let half = simulate_encoder_m(&cfg, &geo, geo.m / 2, None);
        let full = simulate_encoder_m(&cfg, &geo, geo.m, None);
        assert!(quarter.total_cycles < half.total_cycles);
        assert!(half.total_cycles < full.total_cycles);
        // the m-shaped blocks themselves scale near-linearly
        assert!(quarter.per_block["softmax"] * 3 < full.per_block["softmax"]);
        assert!(quarter.per_block["layernorm"] * 3 < full.per_block["layernorm"]);
    }

    #[test]
    fn int4_tier_is_strictly_cheaper_per_layer() {
        // The equal-area INT4 instance (2x2 array, halved weight feed)
        // must beat the INT8 instance it derives from at every length —
        // the cascade's economics depend on it (DESIGN.md §14).
        for name in Geometry::PRESET_NAMES {
            let geo = Geometry::preset(name).unwrap();
            let hw8 = HwConfig::sized_to(&geo);
            let hw4 = hw8.int4_variant();
            for m_eff in [1usize, geo.m / 3 + 1, geo.m] {
                let c8 = simulate_encoder_m(&hw8, &geo, m_eff, None).total_cycles;
                let c4 = simulate_encoder_m(&hw4, &geo, m_eff, None).total_cycles;
                assert!(c4 < c8, "{name} m_eff={m_eff}: int4 {c4} !< int8 {c8}");
            }
        }
    }

    /// Sum of Start→Done durations of one named block over the trace.
    fn block_cycles(trace: &Trace, name: &str) -> u64 {
        use crate::sim::Event;
        let mut open = None;
        let mut total = 0;
        for e in &trace.events {
            match e {
                Event::Start { block, cycle, .. } if *block == name => open = Some(*cycle),
                Event::Done { block, cycle, .. } if *block == name => {
                    total += cycle - open.take().expect("Done without Start");
                }
                _ => {}
            }
        }
        total
    }

    #[test]
    fn data_dependent_iters_drive_ln1_and_ln2_independently() {
        // The functional model emits 2*m iteration counts per layer (ln1
        // rows then ln2 rows); each LayerNorm FSM must consume its own
        // half.  Swapping the halves must swap the two blocks' cycle
        // counts — with the old shared-slice bug both moved together.
        let cfg = HwConfig { worst_case_sqrt: false, ..HwConfig::paper() };
        let geo = Geometry::preset("tiny").unwrap();
        let m = geo.m;
        let run = |ln1: u32, ln2: u32| {
            let mut iters = vec![ln1; m];
            iters.extend(std::iter::repeat(ln2).take(m));
            let mut trace = Trace::default();
            let mut blocks = BTreeMap::new();
            simulate_layer_m(&cfg, &geo, m, 0, &mut trace, &mut blocks, Some(&iters));
            (block_cycles(&trace, "layernorm1"), block_cycles(&trace, "layernorm2"))
        };
        let (a1, a2) = run(30, 2);
        let (b1, b2) = run(2, 30);
        assert!(a1 > a2, "ln1 charged its own (heavy) half: {a1} vs {a2}");
        assert!(b2 > b1, "ln2 charged its own (heavy) half: {b2} vs {b1}");
        assert_eq!(a1, b2, "swapping halves swaps the block costs");
        assert_eq!(a2, b1, "swapping halves swaps the block costs");
    }
}
