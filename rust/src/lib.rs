//! # SwiftTron — integer-only Transformer accelerator, reproduced in software
//!
//! This crate is the Layer-3 coordinator of the three-layer reproduction of
//! *SwiftTron: An Efficient Hardware Accelerator for Quantized Transformers*
//! (Marchisio et al., 2023):
//!
//! * [`quant`] — the bit-exact integer arithmetic the ASIC datapath performs
//!   (dyadic requantization, polynomial exp/erf, iterative integer sqrt,
//!   integer softmax/GELU/LayerNorm).  Functional model of every block.
//! * [`sim`] — a cycle-accurate simulator of the SwiftTron architecture:
//!   MAC-array MatMul, the three-phase Softmax and LayerNorm units, the
//!   MHSA/FFN/LayerNorm FSMs and their handshakes.
//! * [`synthesis`] — a 65 nm gate-level area/power/timing cost model that
//!   stands in for the paper's Synopsys DC flow (DESIGN.md §5).
//! * [`baselines`] — the GPU roofline model and FP32-datapath comparison
//!   points used by the paper's Table II / Table III / Fig. 2.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); python never runs on the request path.
//!   Compiled against the vendored `xla` crate only under the `pjrt`
//!   feature; the default build is dependency-free and serves through the
//!   functional replicas instead.
//! * [`model`] — geometry, weights, and scale metadata shared by all of the
//!   above (read from the artifact manifest).
//! * [`coordinator`] — the multi-tenant concurrent serving pipeline
//!   (DESIGN.md §2, §6, §8, §9): a model registry (named geometry
//!   presets with per-model replica groups, fair-share weights,
//!   `min..=max` replica ranges and SLO classes) in front of a request
//!   router + dynamic batcher (dispatch groups keyed by `(model,
//!   padded length)`, weighted-fair across models, padding waste
//!   metered per model) feeding per-model group runtimes — one
//!   dispatcher thread and one private executor per group, replica
//!   counts moved by an SLO-aware backlog autoscaler — with
//!   per-replica and per-model virtual-time (simulated cycle) and
//!   latency accounting next to wall-clock throughput.
//! * [`workload`] — the open-loop measurement substrate (DESIGN.md §10):
//!   seeded arrival processes (Poisson / bursty MMPP / diurnal ramp),
//!   recorded-trace replay, fault-injecting chaos replicas, and the
//!   driver that paces traces against the coordinator under offered
//!   load instead of closed-loop send-wait-send.
//! * [`wire`] — the `SWWIRE1` binary wire protocol and non-blocking
//!   connection multiplexer (DESIGN.md §11): zero-copy pull decoding
//!   out of fixed per-connection ring buffers, length-prefixed
//!   request/response frames, thousands of connections per I/O thread
//!   with bounded buffers and backpressure, out-of-order completion,
//!   and SLO-derived load shedding (typed `Overloaded` rejections),
//!   with the legacy text protocol behind first-bytes auto-detection.
//! * [`util`] — in-repo substrates (RNG, JSON, CLI, thread pool, property
//!   testing, stats): the offline crate set has no tokio/clap/serde/etc.

pub mod baselines;
pub mod coordinator;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod synthesis;
pub mod util;
pub mod wire;
pub mod workload;
