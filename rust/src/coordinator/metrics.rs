//! Serving metrics: aggregate counters + latency series shared across
//! the pool, plus per-replica accounting that pairs each simulated
//! accelerator's *virtual* time (cycles at the modeled clock) with the
//! wall-clock time its host thread actually spent — so both "how fast is
//! the modeled hardware" and "how fast is this serving process" are
//! reported side by side (DESIGN.md §2).

use crate::util::stats::Series;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One replica's ledger.  `busy_ns` is host wall-clock execution time;
/// `accel_cycles`/`accel_ms` are the simulated accelerator's virtual
/// time for the same requests.
#[derive(Default)]
pub struct ReplicaStats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    /// wall-clock execution time, nanoseconds
    pub busy_ns: AtomicU64,
    /// simulated accelerator cycles across served requests
    pub accel_cycles: AtomicU64,
    /// simulated accelerator milliseconds (virtual time)
    accel_ms: Mutex<f64>,
}

impl ReplicaStats {
    /// Wall-clock seconds this replica spent executing requests.
    pub fn busy_s(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Virtual accelerator milliseconds accumulated by this replica.
    pub fn accel_ms(&self) -> f64 {
        *self.accel_ms.lock().unwrap()
    }
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// live tokens submitted (the sum of request sequence lengths)
    pub actual_tokens: AtomicU64,
    /// tokens after rounding each request up to its dispatch bucket
    /// boundary — what a bucket-configured accelerator would process
    pub padded_tokens: AtomicU64,
    /// end-to-end wallclock latency (seconds)
    pub e2e_s: Mutex<Series>,
    /// time spent queued before dispatch (seconds)
    pub queue_s: Mutex<Series>,
    /// execution wallclock (seconds)
    pub exec_s: Mutex<Series>,
    /// simulated accelerator time (milliseconds of virtual time)
    pub accel_ms: Mutex<Series>,
    /// per-replica ledgers, sized by the pool at startup
    replicas: Mutex<Vec<Arc<ReplicaStats>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Size the per-replica ledger (idempotent; only ever grows).
    pub fn ensure_replicas(&self, n: usize) {
        let mut r = self.replicas.lock().unwrap();
        while r.len() < n {
            r.push(Arc::new(ReplicaStats::default()));
        }
    }

    /// Ledger of replica `i` (created on demand).
    pub fn replica(&self, i: usize) -> Arc<ReplicaStats> {
        let mut r = self.replicas.lock().unwrap();
        while r.len() <= i {
            r.push(Arc::new(ReplicaStats::default()));
        }
        Arc::clone(&r[i])
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.lock().unwrap().len()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one request's live token count and the padded count its
    /// dispatch bucket charges (equal when bucketing is off).
    pub fn record_tokens(&self, actual: usize, padded: usize) {
        self.actual_tokens.fetch_add(actual as u64, Ordering::Relaxed);
        self.padded_tokens.fetch_add(padded as u64, Ordering::Relaxed);
    }

    /// Fraction of bucket-padded tokens that carry no live data:
    /// `(padded - actual) / padded`.  0 when bucketing is off or
    /// nothing was submitted.
    pub fn padding_waste(&self) -> f64 {
        let actual = self.actual_tokens.load(Ordering::Relaxed);
        let padded = self.padded_tokens.load(Ordering::Relaxed);
        if padded == 0 {
            0.0
        } else {
            (padded.saturating_sub(actual)) as f64 / padded as f64
        }
    }

    pub fn record_completion(&self, e2e: f64, queued: f64, exec: f64, accel_ms: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.e2e_s.lock().unwrap().push(e2e);
        self.queue_s.lock().unwrap().push(queued);
        self.exec_s.lock().unwrap().push(exec);
        self.accel_ms.lock().unwrap().push(accel_ms);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one served request against replica `i`'s ledger.
    pub fn record_replica(&self, i: usize, exec_s: f64, cycles: u64, accel_ms: f64, error: bool) {
        let r = self.replica(i);
        r.requests.fetch_add(1, Ordering::Relaxed);
        if error {
            r.errors.fetch_add(1, Ordering::Relaxed);
        }
        r.busy_ns.fetch_add((exec_s * 1e9) as u64, Ordering::Relaxed);
        r.accel_cycles.fetch_add(cycles, Ordering::Relaxed);
        *r.accel_ms.lock().unwrap() += accel_ms;
    }

    /// Virtual accelerator milliseconds summed over all replicas.
    pub fn total_accel_ms(&self) -> f64 {
        self.replicas.lock().unwrap().iter().map(|r| r.accel_ms()).sum()
    }

    pub fn report(&self) -> String {
        let done = self.completed.load(Ordering::Relaxed);
        let req = self.requests.load(Ordering::Relaxed);
        let err = self.errors.load(Ordering::Relaxed);
        let mut out = format!(
            "requests={req} completed={done} errors={err}\n  e2e   {}\n  queue {}\n  exec  {}\n  accel {}\n  tokens actual={} padded={} waste={:.1}%",
            self.e2e_s.lock().unwrap().summary("s"),
            self.queue_s.lock().unwrap().summary("s"),
            self.exec_s.lock().unwrap().summary("s"),
            self.accel_ms.lock().unwrap().summary("ms"),
            self.actual_tokens.load(Ordering::Relaxed),
            self.padded_tokens.load(Ordering::Relaxed),
            100.0 * self.padding_waste(),
        );
        for (i, r) in self.replicas.lock().unwrap().iter().enumerate() {
            out.push_str(&format!(
                "\n  replica {i}: requests={} errors={} busy={:.3}s virtual={:.3}ms ({} cycles)",
                r.requests.load(Ordering::Relaxed),
                r.errors.load(Ordering::Relaxed),
                r.busy_s(),
                r.accel_ms(),
                r.accel_cycles.load(Ordering::Relaxed),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_series_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_completion(0.01, 0.001, 0.008, 0.03);
        m.record_error();
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("completed=1"));
    }

    #[test]
    fn replica_ledgers_track_virtual_and_wall_time() {
        let m = Metrics::new();
        m.ensure_replicas(2);
        assert_eq!(m.replica_count(), 2);
        m.record_replica(0, 0.002, 1_000, 0.007, false);
        m.record_replica(0, 0.002, 1_000, 0.007, false);
        m.record_replica(1, 0.004, 2_000, 0.014, true);
        let r0 = m.replica(0);
        assert_eq!(r0.requests.load(Ordering::Relaxed), 2);
        assert_eq!(r0.accel_cycles.load(Ordering::Relaxed), 2_000);
        assert!((r0.busy_s() - 0.004).abs() < 1e-9);
        assert!((r0.accel_ms() - 0.014).abs() < 1e-12);
        assert_eq!(m.replica(1).errors.load(Ordering::Relaxed), 1);
        assert!((m.total_accel_ms() - 0.028).abs() < 1e-12);
        let report = m.report();
        assert!(report.contains("replica 0:"));
        assert!(report.contains("replica 1:"));
    }

    #[test]
    fn padding_waste_tracks_bucket_overhead() {
        let m = Metrics::new();
        assert_eq!(m.padding_waste(), 0.0, "no traffic, no waste");
        m.record_tokens(3, 8);
        m.record_tokens(5, 8);
        m.record_tokens(16, 16);
        assert_eq!(m.actual_tokens.load(Ordering::Relaxed), 24);
        assert_eq!(m.padded_tokens.load(Ordering::Relaxed), 32);
        assert!((m.padding_waste() - 0.25).abs() < 1e-12);
        assert!(m.report().contains("waste=25.0%"));
    }

    #[test]
    fn replica_ledger_grows_on_demand() {
        let m = Metrics::new();
        m.record_replica(3, 0.001, 10, 0.0, false);
        assert_eq!(m.replica_count(), 4);
        assert_eq!(m.replica(3).requests.load(Ordering::Relaxed), 1);
    }
}
