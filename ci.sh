#!/usr/bin/env bash
# Tier-1 gate: release build, tests, formatting, clippy, and rustdoc
# with warnings denied — the doc pass makes dangling references (e.g.
# to DESIGN.md sections that were renamed away) fail fast instead of
# rotting.  `set -euo pipefail` makes every stage a hard gate: a
# mid-script failure (or formatting drift at the fmt stage) stops the
# pipeline instead of scrolling past.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q

# Integration-test timing summary: each [[test]] target re-run on its
# own (--nocapture streams long-running targets live) with wall seconds
# per target, so a slow suite is visible before it creeps into minutes.
echo "-- integration-test timing (cargo test -q --test '*' -- --nocapture) --"
suite_start=$SECONDS
for t in $(awk '/^\[\[test\]\]/{grab=1;next} grab&&/^name = /{gsub(/"/,""); print $3; grab=0}' Cargo.toml); do
  t_start=$SECONDS
  cargo test -q --test "$t" -- --nocapture
  echo "  $t: $((SECONDS-t_start))s"
done
echo "  total: $((SECONDS-suite_start))s"

# The pjrt feature must keep compiling against the in-repo xla stub
# (check-only: there is no real PJRT client to run against here).
cargo check --features pjrt --all-targets

cargo fmt --check
cargo clippy --all-targets -- -D warnings
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
echo "ci.sh: all green"
