//! Table III — feature comparison between SwiftTron and the related
//! works, regenerated verbatim from the encoded matrix.

use swifttron::baselines::comparison_table;
use swifttron::util::bench::Table;

fn main() {
    let mut t = Table::new(&[
        "work", "HW implementation", "bit-width", "complete arch", "nonlinear computation",
    ]);
    for w in comparison_table() {
        let hw = match w.hw {
            swifttron::baselines::comparison::HwTarget::Asic(n) => format!("ASIC {n}"),
            swifttron::baselines::comparison::HwTarget::Fpga(n) => format!("FPGA {n}"),
            swifttron::baselines::comparison::HwTarget::Gpu(n) => format!("GPU {n} (x)"),
        };
        let nl = match w.nonlinear {
            swifttron::baselines::comparison::NonlinearImpl::IntegerApprox => "integers (approx) [ok]",
            swifttron::baselines::comparison::NonlinearImpl::Lut => "LUT (x)",
            swifttron::baselines::comparison::NonlinearImpl::Fft => "integers w/ FFT (x)",
            swifttron::baselines::comparison::NonlinearImpl::Fp16 => "FP16/FP32 (x)",
            swifttron::baselines::comparison::NonlinearImpl::Fp32 => "FP32 (x)",
            swifttron::baselines::comparison::NonlinearImpl::NotApplicable => "N/A (x)",
        };
        t.row(&[
            w.name.to_string(),
            hw,
            format!("{}{}", w.bitwidth, if w.bitwidth_ok { " [ok]" } else { " (x)" }),
            if w.complete_architecture { "yes [ok]".into() } else { "no (x)".to_string() },
            nl.to_string(),
        ]);
    }
    t.print("Table III — related-work feature comparison");

    let winners: Vec<&str> = comparison_table()
        .iter()
        .filter(|w| w.all_features())
        .map(|w| w.name)
        .collect();
    println!("\ndesigns with every feature: {winners:?} (paper claim: only SwiftTron)");
}
