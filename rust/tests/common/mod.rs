//! Shared integration-test fixtures (ISSUE 4): seeded RNG construction,
//! randomized small geometries, synthetic weight stacks, canonical
//! token/activation streams, and functional replica groups — the setup
//! every serving/datapath test used to copy-paste.
//!
//! Each integration-test binary compiles its own copy and uses a
//! subset, hence the file-level `dead_code` allowance.

#![allow(dead_code)]

use std::sync::Arc;
use swifttron::coordinator::{EngineReplica, FunctionalEngine, ModelGroup};
use swifttron::model::{Geometry, LayerConsts};
use swifttron::sim::functional::{synthetic_consts, LayerWeights};
use swifttron::sim::HwConfig;
use swifttron::util::rng::Rng;
use swifttron::workload::DelayReplica;

/// Random small single-layer geometry for head-partitioning tests:
/// always multi-head (heads 2..=4, dh in {4, 8, 12}) so the parallel
/// head loop has a real cross-head surface.  With `with_tail`, `d`
/// exceeds `heads * dh` by `1..heads` columns — the attention tail the
/// head loop never touches and must leave zeroed (`Geometry::dh`
/// floors, so a sub-`heads` tail keeps `dh()` intact).
pub fn random_geo(rng: &mut Rng, with_tail: bool) -> Geometry {
    let heads = 2 + rng.below(3) as usize; // 2..=4
    let dh = 4 * (1 + rng.below(3) as usize); // 4, 8, 12
    let tail = if with_tail { 1 + rng.below(heads as u64 - 1) as usize } else { 0 };
    let d = heads * dh + tail;
    let m = 4 + rng.below(13) as usize; // 4..=16
    let dff = 8 * (1 + rng.below(4) as usize); // 8..=32
    Geometry::new(d, heads, m, dff, 1)
}

/// Random small single-layer geometry including single-head cases
/// (heads 1..=3, `d` an exact multiple of the head count) — the
/// variable-length suite's sampler, where degenerate head counts are
/// part of the coverage.
pub fn random_geo_small(rng: &mut Rng) -> Geometry {
    let heads = 1 + rng.below(3) as usize; // 1..=3
    let dh = 4 * (1 + rng.below(3) as usize); // 4, 8, 12
    let d = heads * dh;
    let m = 4 + rng.below(13) as usize; // 4..=16
    let dff = 8 * (1 + rng.below(4) as usize); // 8..=32
    Geometry::new(d, heads, m, dff, 1)
}

/// Synthetic per-layer weight/constant stack for `geo.layers` layers.
pub fn synthetic_layers(rng: &mut Rng, geo: &Geometry) -> Vec<(LayerWeights, LayerConsts)> {
    (0..geo.layers)
        .map(|_| (LayerWeights::synthetic(rng, geo), synthetic_consts(geo)))
        .collect()
}

/// Random INT8 activations (`n` values in -127..=127).
pub fn random_acts(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.range_i64(-127, 127) as i32).collect()
}

/// Random token stream over the synthetic engines' 64-entry vocab.
pub fn random_tokens(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.below(60) as i32).collect()
}

/// The canonical deterministic token stream the serving tests compare
/// across replicas/backends: `i % 60` for `len` positions.
pub fn canonical_tokens(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i % 60) as i32).collect()
}

/// `n` identical functional replicas of a preset on the paper hardware
/// instance (one shared synthetic weight bundle).
pub fn functional_replicas(preset: &str, seed: u64, n: usize) -> Vec<Arc<dyn EngineReplica>> {
    FunctionalEngine::replica_group(preset, seed, HwConfig::paper(), n)
        .expect("synthetic replica group")
}

/// `n` fixed single-replica tenant groups `t0..t{n-1}` of zero-delay
/// mock replicas, weighted `1..=n` — the many-tenant universe behind
/// the sharded-dispatch contention legs (DESIGN.md §13): enough model
/// shards that submit-side contention, not replica service time, is
/// what the test or bench measures.
pub fn tenant_groups(n: usize) -> Vec<ModelGroup> {
    (0..n)
        .map(|i| {
            let replicas: Vec<Arc<dyn EngineReplica>> =
                vec![Arc::new(DelayReplica::from_ms(0))];
            ModelGroup::fixed(format!("t{i}"), replicas, i as u64 + 1)
        })
        .collect()
}
