//! SwiftTron block-level area rollup (Fig. 5's component list) and the
//! activity-weighted power model behind the paper's Fig. 18 breakdowns.

use super::operators::Operators;
use super::tech::Tech65;
use crate::model::Geometry;
use crate::sim::{encoder::LatencyReport, HwConfig};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ComponentCost {
    pub name: &'static str,
    pub ge: f64,
    pub area_mm2: f64,
    /// duty-cycle (busy fraction) during an inference, from the simulator
    pub duty: f64,
    pub power_w: f64,
}

/// Gate count of one Softmax unit (Fig. 11): max comparator, the
/// polynomial datapath (adders + one 32b squarer), the z-shifter, the
/// exponent accumulator, the output divider, a row buffer holding the
/// m exp values awaiting the denominator, and 3-stage pipeline registers.
fn softmax_unit_ge(m: usize) -> f64 {
    let cmp = Operators::comparator(32).ge;
    let poly = 2.0 * Operators::int_adder(32).ge + Operators::int_multiplier(32, 32).ge;
    let shift = Operators::barrel_shifter(32).ge;
    // the denominator is an INT64 accumulation (spec: full-width exp sums)
    let acc = Operators::int_adder(64).ge + Operators::register(64).ge;
    // one division per element per cycle: 64-bit array divider
    let divider = Operators::array_divider(64).ge;
    let row_buffer = m as f64 * Operators::register(32).ge;
    let pipeline = 3.0 * 4.0 * Operators::register(32).ge;
    cmp + poly + shift + acc + divider + row_buffer + pipeline + 200.0
}

/// Gate count of one LayerNorm lane (Fig. 15): subtract, 32b squarer,
/// the per-lane divider + affine MAC, three phase registers, plus an
/// amortized share of the reduction tree and the iterative sqrt unit.
fn layernorm_lane_ge() -> f64 {
    let sub = Operators::int_adder(32).ge;
    // variance squares are 64-bit full-width products (spec)
    let square = Operators::int_multiplier(32, 32).ge;
    // normalized output needs one (y<<7)/std division per element per
    // cycle: 64-bit array divider per lane (see Fig. 18 discussion)
    let divider = Operators::array_divider(64).ge;
    let affine = Operators::int_multiplier(32, 8).ge + Operators::int_adder(32).ge;
    let phase_regs = 3.0 * Operators::register(64).ge;
    // reduction tree: one 64b adder per lane amortizes the binary tree;
    // sqrt unit (adder+shifter+2 regs) is shared across the row
    let tree = Operators::int_adder(64).ge;
    let sqrt_share = (Operators::int_adder(64).ge
        + Operators::barrel_shifter(64).ge
        + 2.0 * Operators::register(64).ge)
        / 64.0;
    sub + square + divider + affine + phase_regs + tree + sqrt_share + 100.0
}

/// Gate count of one GELU lane (Fig. 14): the erf polynomial (adders +
/// squarer) and the output multiplier, with sign handling.
fn gelu_lane_ge() -> f64 {
    let poly = 2.0 * Operators::int_adder(32).ge + Operators::int_multiplier(32, 32).ge;
    let out_mul = Operators::int_multiplier(32, 32).ge;
    let sign = 80.0;
    poly + out_mul + sign + 3.0 * Operators::register(32).ge
}

/// Gate count of one Requantization lane (Fig. 7): INT32 multiplier +
/// shifter + saturation.
fn requant_lane_ge() -> f64 {
    Operators::int_multiplier(32, 16).ge + Operators::barrel_shifter(32).ge + 60.0
}

/// Area/power of every component of a SwiftTron instance executing
/// `geo`, with duty factors from the simulated `report`.
pub fn component_breakdown(
    t: &Tech65,
    cfg: &HwConfig,
    geo: &Geometry,
    report: &LatencyReport,
) -> Vec<ComponentCost> {
    let freq = 1e9 / cfg.clock_ns;
    let total_cycles = report.total_cycles.max(1) as f64;
    // busy cycles per block class from the simulator; the MatMul busy
    // count aggregates central-array and head-unit activity
    let busy = |k: &str| report.per_block.get(k).copied().unwrap_or(0) as f64;

    // --- MatMul: the central R x C MAC array.  The attention heads map
    // onto per-head column slices (array_cols = heads * dh in the paper
    // configuration), so the array is counted once (§III-D: components
    // "can be shared and/or reused").
    let mac = Operators::int8_mac();
    let matmul_ge = cfg.mac_count() as f64 * mac.ge
        // operand staging registers along both edges
        + (cfg.array_rows + cfg.array_cols) as f64 * Operators::register(8).ge
        // output column mux (one 32b 2:1 mux-equivalent per row per level)
        + cfg.array_rows as f64 * 32.0 * 3.0;

    let softmax_ge = cfg.softmax_units as f64 * softmax_unit_ge(geo.m);
    let layernorm_ge = cfg.layernorm_lanes as f64 * layernorm_lane_ge();
    // GELU/Requant lanes match the array's column readout width (one
    // column of `array_rows` values drains per cycle).
    let gelu_ge = cfg.array_rows as f64 * gelu_lane_ge();
    let requant_ge = cfg.array_rows as f64 * requant_lane_ge();
    // control unit: three FSMs + handshake glue (small, fixed)
    let control_ge = 30_000.0;

    let duty_matmul = (busy("matmul") / total_cycles).min(1.0);
    let duty_softmax = (busy("softmax") / total_cycles).min(1.0);
    let duty_ln = (busy("layernorm") / total_cycles).min(1.0);
    // overlapped lanes are busy whenever a matmul drains outputs
    let duty_gelu = (busy("gelu").max(busy("matmul") * 0.08) / total_cycles).min(1.0);
    let duty_req = (busy("requant").max(busy("matmul") * 0.2) / total_cycles).min(1.0);

    let mk = |name, ge: f64, duty: f64, activity: f64| ComponentCost {
        name,
        ge,
        area_mm2: t.area_mm2(ge),
        duty,
        power_w: t.dyn_power_w(ge, activity * duty, freq) + t.leak_power_w(ge),
    };

    vec![
        mk("MatMul", matmul_ge, duty_matmul, mac.activity),
        mk("Softmax", softmax_ge, duty_softmax, 0.22),
        mk("LayerNorm", layernorm_ge, duty_ln, 0.18),
        mk("GELU", gelu_ge, duty_gelu, 0.25),
        mk("Requant", requant_ge, duty_req, 0.25),
        mk("Control", control_ge, 1.0, 0.1),
    ]
}

/// Totals across a breakdown.
pub fn totals(parts: &[ComponentCost]) -> (f64, f64) {
    (
        parts.iter().map(|p| p.area_mm2).sum(),
        parts.iter().map(|p| p.power_w).sum(),
    )
}

/// Percentage maps (area, power) keyed by component name — Fig. 18.
pub fn percentages(parts: &[ComponentCost]) -> (BTreeMap<&'static str, f64>, BTreeMap<&'static str, f64>) {
    let (a_tot, p_tot) = totals(parts);
    let mut a = BTreeMap::new();
    let mut p = BTreeMap::new();
    for c in parts {
        a.insert(c.name, 100.0 * c.area_mm2 / a_tot);
        p.insert(c.name, 100.0 * c.power_w / p_tot);
    }
    (a, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::simulate_encoder;

    fn setup() -> (Tech65, HwConfig, Geometry, LatencyReport) {
        let t = Tech65::new();
        let cfg = HwConfig::paper();
        let geo = Geometry::preset("roberta_base").unwrap();
        let r = simulate_encoder(&cfg, &geo);
        (t, cfg, geo, r)
    }

    #[test]
    fn matmul_dominates_area_and_power() {
        let (t, cfg, geo, r) = setup();
        let parts = component_breakdown(&t, &cfg, &geo, &r);
        let (a, p) = percentages(&parts);
        assert!(a["MatMul"] > 45.0, "area% {:?}", a);
        assert!(p["MatMul"] > 50.0, "power% {:?}", p);
    }

    #[test]
    fn layernorm_area_heavy_power_light() {
        // the paper's Fig. 18 signature: LN 25% area but only 6% power
        let (t, cfg, geo, r) = setup();
        let parts = component_breakdown(&t, &cfg, &geo, &r);
        let (a, p) = percentages(&parts);
        assert!(a["LayerNorm"] > p["LayerNorm"], "{a:?} vs {p:?}");
    }

    #[test]
    fn gelu_is_small() {
        let (t, cfg, geo, r) = setup();
        let parts = component_breakdown(&t, &cfg, &geo, &r);
        let (a, p) = percentages(&parts);
        assert!(a["GELU"] < 10.0 && p["GELU"] < 10.0);
    }

    #[test]
    fn total_area_paper_order_of_magnitude() {
        // paper Table I: 273 mm^2 — we require the same order (100..600)
        let (t, cfg, geo, r) = setup();
        let parts = component_breakdown(&t, &cfg, &geo, &r);
        let (area, _) = totals(&parts);
        assert!((100.0..600.0).contains(&area), "area {area} mm^2");
    }
}
