"""Pallas Requantization kernel (paper Fig. 7).

INT32 -> INT8 via a dyadic multiply + arithmetic right shift + saturation.
Elementwise over VMEM tiles; the dyadic constants (b, c) are design-time
constants baked into the lowered HLO, exactly as the ASIC hard-wires them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..intops import Dyadic, INT8_MAX, INT8_MIN


def _requant_kernel(q_ref, o_ref, *, b: int, c: int, lo: int, hi: int):
    q = q_ref[...].astype(jnp.int64)
    shifted = (q * jnp.int64(b)) >> jnp.int64(c)
    o_ref[...] = jnp.clip(shifted, lo, hi).astype(jnp.int32)


def _pick_block(dim: int, preferred: int) -> int:
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("dy", "lo", "hi", "bm", "bn"))
def requantize(q, dy: Dyadic, lo: int = INT8_MIN, hi: int = INT8_MAX,
               *, bm: int = 256, bn: int = 512):
    """Requantize an INT32 (m, n) tensor to the INT8 range (stored INT32)."""
    m, n = q.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_requant_kernel, b=dy.b, c=dy.c, lo=lo, hi=hi),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(q)
