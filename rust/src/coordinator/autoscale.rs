//! SLO-aware backlog autoscaler (DESIGN.md §9): a small control loop
//! that moves each scalable model group's replica count within its
//! `min..=max` bounds as the group's backlog-vs-SLO ratio crosses
//! hysteresis thresholds.
//!
//! The demand signal per tick is the *estimated drain time* of the
//! model's live backlog: its predicted work in milliseconds
//! ([`predicted_work_ms`]) divided by the replicas currently serving.
//! Groups registered with an analytical [`CostModel`] price the
//! backlog in predicted accelerator cycles (`backlog_cost ×
//! ms_per_cost`), so a freshly registered heavy model scores its true
//! work from the very first tick — before any completion lands, the
//! model's own `ms_per_cycle` clock prior calibrates the estimate,
//! never a shared scalar guess.  Cost-less custom groups keep the
//! legacy `backlog_requests × mean_exec_ms` estimate (with
//! `default_service_ms` as the pre-completion prior).  Judged against
//! the model's `slo_ms` latency class:
//!
//! ```text
//!            drain_ms > grow_ratio · slo, below max ──► GROW  (spawn replica
//!                                                       from the factory,
//!                                                       shared Arc weights)
//!   shrink_ratio · slo > drain_ms, above min      ──► SHRINK (drain-then-
//!                                                       retire one replica)
//!            otherwise                             ──► HOLD
//! ```
//!
//! Hysteresis is two-fold: the dead band between `shrink_ratio` and
//! `grow_ratio` (a group sitting near its SLO neither grows nor
//! shrinks), plus a per-group cooldown of `hold_ticks` ticks after any
//! applied action so one burst cannot slam the group from min to max
//! and back within a few control intervals.  The decision function
//! [`decide`] is pure and unit-tested; the loop in
//! `coordinator::router` merely samples the signals and applies it.
//!
//! Floor repair (DESIGN.md §10): a group observed *below* its `min` —
//! possible only because the pool retires a panicked replica's slot on
//! the spot — is regrown immediately, bypassing both the cooldown and
//! the SLO gate; losing a replica is a fault to heal, not a load signal
//! to damp.  Any group with a factory gets this, including fixed-size
//! `min == max` groups the policy half of the loop never touches.

use super::metrics::{Metrics, ModelStats};
use super::pool::GroupRuntime;
use crate::sim::CostModel;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Autoscaler tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct AutoscalePolicy {
    /// Control-loop tick interval.
    pub interval: Duration,
    /// Grow when estimated drain time exceeds `grow_ratio · slo_ms`.
    pub grow_ratio: f64,
    /// Shrink when estimated drain time falls below
    /// `shrink_ratio · slo_ms` (must sit well below `grow_ratio` — the
    /// gap is the hysteresis dead band).
    pub shrink_ratio: f64,
    /// Ticks a group holds after an applied grow/shrink before it may
    /// act again (cooldown half of the hysteresis).
    pub hold_ticks: u32,
    /// Service-time prior (ms per request) before a model's first
    /// completion.  Only consulted for groups *without* a
    /// [`CostModel`]: cost-modeled groups derive their cold-start
    /// prior from their own hardware clock (`CostModel::ms_per_cycle`)
    /// instead of a shared scalar guess.
    pub default_service_ms: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            interval: Duration::from_millis(5),
            grow_ratio: 1.0,
            shrink_ratio: 0.25,
            hold_ticks: 2,
            default_service_ms: 1.0,
        }
    }
}

/// One tick's verdict for one group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    Grow,
    Shrink,
    Hold,
}

/// Predicted milliseconds of work sitting in one model's backlog — the
/// single demand signal behind autoscaling ([`tick_group`]), the
/// router's queueing-delay estimate, and wire admission control.
///
/// With a [`CostModel`] the estimate is `backlog_cost ×
/// ms-per-cost-unit`: the live gauge of predicted accelerator cycles
/// submitted but not yet settled, times the measured wall-milliseconds
/// each predicted cycle has actually cost so far
/// ([`ModelStats::ms_per_cost`]).  Before the first completion the
/// model's own analytical clock ([`CostModel::ms_per_cycle`]) stands
/// in, so a heavy model is scored as heavy from its very first queued
/// request — the cold-start blind spot of the request-count signal.
///
/// Without a cost model (custom engine groups) the legacy estimate
/// remains: `backlog` requests times the model's measured mean
/// execution time, with `fallback_ms` as the pre-completion prior.
pub fn predicted_work_ms(
    stats: &ModelStats,
    cost_model: Option<&CostModel>,
    backlog: usize,
    fallback_ms: f64,
) -> f64 {
    match cost_model {
        Some(cm) => {
            let cost = stats.backlog_cost.load(Ordering::Relaxed) as f64;
            cost * stats.ms_per_cost().unwrap_or_else(|| cm.ms_per_cycle())
        }
        None => backlog as f64 * stats.mean_exec_ms(fallback_ms),
    }
}

/// Pure scaling decision for one group at one tick: the group's
/// predicted backlog work ([`predicted_work_ms`]), active replica
/// count and bounds, and its SLO class.
///
/// `active == 0` (every slot retired by fault recovery) is clamped to
/// 1, and a NaN work estimate — possible when the signal chain divides
/// a zero-sample window — is treated as *infinite* drain time.  Both
/// IEEE escapes otherwise read as "no work": every NaN comparison is
/// false, so a group with a poisoned estimate would silently Hold at
/// zero replicas forever instead of growing back toward its SLO.
pub fn decide(
    work_ms: f64,
    active: usize,
    min: usize,
    max: usize,
    slo_ms: f64,
    policy: &AutoscalePolicy,
) -> ScaleDecision {
    let active = active.max(1);
    let raw = work_ms / active as f64;
    let drain_ms = if raw.is_nan() { f64::INFINITY } else { raw };
    if drain_ms > policy.grow_ratio * slo_ms && active < max {
        ScaleDecision::Grow
    } else if drain_ms < policy.shrink_ratio * slo_ms && active > min {
        ScaleDecision::Shrink
    } else {
        ScaleDecision::Hold
    }
}

/// Per-group cooldown state for the control loop.
pub struct GroupScaleState {
    cooldown: u32,
}

impl GroupScaleState {
    pub fn new() -> GroupScaleState {
        GroupScaleState { cooldown: 0 }
    }
}

impl Default for GroupScaleState {
    fn default() -> Self {
        GroupScaleState::new()
    }
}

/// One autoscaler tick over one group: sample the signals, apply
/// [`decide`] under the cooldown, execute the action on the runtime.
/// Returns the decision actually applied (Hold during cooldown or when
/// the runtime refused).  `queued` is the group's batcher backlog
/// (queued + in flight), sampled by the caller under the batcher lock.
pub fn tick_group(
    rt: &Arc<GroupRuntime>,
    state: &mut GroupScaleState,
    queued: usize,
    metrics: &Metrics,
    policy: &AutoscalePolicy,
) -> ScaleDecision {
    // Floor repair outranks both the cooldown and the SLO gate: a group
    // below its `min` lost a replica to a fault (panic retirement),
    // which is a capacity hole to fix now, not a load signal to damp.
    // Applies to any group with a factory — `scalable()` (max > min and
    // an SLO class) is not required to get back to the floor.
    let (min, _) = rt.replica_bounds();
    if rt.active_replicas() < min && rt.can_respawn() {
        match rt.grow() {
            Ok(true) => {
                state.cooldown = policy.hold_ticks;
                return ScaleDecision::Grow;
            }
            Ok(false) => {}
            Err(e) => {
                eprintln!("autoscaler: model {:?} floor repair failed: {e}", rt.model());
                state.cooldown = policy.hold_ticks;
                return ScaleDecision::Hold;
            }
        }
    }
    if state.cooldown > 0 {
        state.cooldown -= 1;
        return ScaleDecision::Hold;
    }
    let Some(slo_ms) = rt.slo_ms() else { return ScaleDecision::Hold };
    let (min, max) = rt.replica_bounds();
    let active = rt.active_replicas();
    let stats = metrics.model(rt.model_index());
    let work_ms =
        predicted_work_ms(&stats, rt.cost_model(), queued, policy.default_service_ms);
    let decision = decide(work_ms, active, min, max, slo_ms, policy);
    let applied = match decision {
        ScaleDecision::Grow => match rt.grow() {
            Ok(applied) => applied,
            Err(e) => {
                // A failing factory must not fail silently: the group
                // would sit pinned at its floor blowing its SLO with
                // nothing explaining why.  Surface it, and take the
                // normal cooldown before retrying — a persistent
                // failure must not be re-invoked (and re-logged) at
                // tick frequency.
                eprintln!("autoscaler: model {:?} replica spawn failed: {e}", rt.model());
                state.cooldown = policy.hold_ticks;
                false
            }
        },
        ScaleDecision::Shrink => rt.shrink(),
        ScaleDecision::Hold => false,
    };
    if applied {
        state.cooldown = policy.hold_ticks;
        decision
    } else {
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AutoscalePolicy {
        AutoscalePolicy {
            interval: Duration::from_millis(1),
            grow_ratio: 1.0,
            shrink_ratio: 0.25,
            hold_ticks: 2,
            default_service_ms: 1.0,
        }
    }

    #[test]
    fn grows_when_drain_time_exceeds_slo() {
        // 200 ms of predicted work / 1 replica vs 20 ms SLO
        let p = policy();
        assert_eq!(decide(200.0, 1, 1, 4, 20.0, &p), ScaleDecision::Grow);
        // at max: hold, never exceed the bound
        assert_eq!(decide(200.0, 4, 1, 4, 20.0, &p), ScaleDecision::Hold);
    }

    #[test]
    fn shrinks_only_below_the_dead_band_and_above_min() {
        let p = policy();
        // idle: 0 ms drain < 0.25 x 20 ms
        assert_eq!(decide(0.0, 4, 1, 4, 20.0, &p), ScaleDecision::Shrink);
        // at min: hold
        assert_eq!(decide(0.0, 1, 1, 4, 20.0, &p), ScaleDecision::Hold);
        // inside the dead band (drain 10 ms, band 5..20 ms): hold —
        // a group near its SLO must not flap
        assert_eq!(decide(40.0, 4, 1, 4, 20.0, &p), ScaleDecision::Hold);
    }

    #[test]
    fn capacity_scales_the_drain_estimate() {
        let p = policy();
        // the same 80 ms of work that overwhelms 1 replica is inside
        // the SLO for 4: 80 / 1 = 80 ms vs 80 / 4 = 20 ms against 30
        assert_eq!(decide(80.0, 1, 1, 4, 30.0, &p), ScaleDecision::Grow);
        assert_eq!(decide(80.0, 4, 1, 4, 30.0, &p), ScaleDecision::Hold);
    }

    #[test]
    fn zero_active_is_treated_as_one_not_a_division_by_zero() {
        let p = policy();
        assert_eq!(decide(200.0, 0, 1, 4, 1.0, &p), ScaleDecision::Grow);
        // the drain estimate must come out finite, not inf/NaN — the
        // clamp is what keeps a fully-retired group's signal usable
        assert!((200.0 / 0usize.max(1) as f64).is_finite());
    }

    #[test]
    fn nan_work_estimate_grows_instead_of_silently_holding() {
        // NaN compares false on both gates, which without the guard
        // reads as "no work" and pins a faulted group at zero replicas
        let p = policy();
        assert_eq!(decide(f64::NAN, 0, 1, 4, 20.0, &p), ScaleDecision::Grow);
        assert_eq!(decide(f64::NAN, 1, 1, 4, 20.0, &p), ScaleDecision::Grow);
        // at max the bound still wins: never grow past it
        assert_eq!(decide(f64::NAN, 4, 1, 4, 20.0, &p), ScaleDecision::Hold);
        // infinite work behaves the same way (the guard maps NaN onto
        // this already-correct path)
        assert_eq!(decide(f64::INFINITY, 1, 1, 4, 20.0, &p), ScaleDecision::Grow);
    }

    #[test]
    fn cost_modeled_backlog_scores_work_before_any_completion() {
        use crate::model::Geometry;
        use crate::sim::{CostModel, HwConfig};
        let geo = Geometry::preset("roberta_base").unwrap();
        let hw = HwConfig::sized_to(&geo);
        let cm = CostModel::build(&hw, &geo).unwrap();
        let metrics = Metrics::new();
        let cycles = cm.predict_cycles(geo.m);
        for _ in 0..8 {
            metrics.record_request_for(0, cycles);
        }
        let stats = metrics.model(0);
        // fallback prior poisoned to zero: a cost-modeled group must
        // score its backlog off its own analytical clock, not the
        // shared scalar guess — the legacy path sees no work at all
        let work = predicted_work_ms(&stats, Some(&cm), 8, 0.0);
        let expect = 8.0 * cm.predict_ms(geo.m);
        assert!(
            (work - expect).abs() <= 1e-9 * expect,
            "cold-start work {work} ms != 8 requests x {expect} ms / 8"
        );
        assert!(work > 0.0);
        assert_eq!(predicted_work_ms(&stats, None, 8, 0.0), 0.0);
    }

    #[test]
    fn measured_ms_per_cost_overrides_the_analytical_prior() {
        use crate::model::Geometry;
        use crate::sim::{CostModel, HwConfig};
        let geo = Geometry::preset("tiny").unwrap();
        let hw = HwConfig::sized_to(&geo);
        let cm = CostModel::build(&hw, &geo).unwrap();
        let metrics = Metrics::new();
        // two completions: 2000 cost units at 2 ms exec each
        for _ in 0..2 {
            metrics.record_request_for(0, 1000);
            metrics.record_model_served(0, 8, 8, 1000, 1000, 0.001, 0.002, 0.002, false);
        }
        // backlog of 3000 cost units x measured 0.002 ms/cost = 6 ms
        metrics.record_request_for(0, 3000);
        let stats = metrics.model(0);
        let work = predicted_work_ms(&stats, Some(&cm), 1, 99.0);
        assert!((work - 6.0).abs() < 1e-9, "calibrated work {work} != 6 ms");
    }
}
