//! Serving-scaling sweep (EXPERIMENTS.md §Scaling): closed-loop request
//! throughput of the parallel serving pipeline over replica count ×
//! dispatch-group size, on the tiny preset's artifact-free functional
//! replicas — plus the serial-vs-tiled `i_matmul` kernel comparison that
//! motivates the `PAR_MIN_MACS` threshold.
//!
//! Run: `cargo bench --bench serving_scaling`
//!
//! The acceptance claim this bench demonstrates: more than one replica
//! yields higher request throughput than the single-replica path on the
//! same workload (printed as the speedup column; >1.0x from 2 replicas
//! up on any multi-core host).

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};
use swifttron::coordinator::{BatchPolicy, EngineReplica, FunctionalEngine, Metrics, Router};
use swifttron::quant::{i_matmul, i_matmul_tiled};
use swifttron::sim::HwConfig;
use swifttron::util::bench::{fmt_time, Bench, Table};
use swifttron::util::rng::Rng;
use swifttron::util::threadpool::default_parallelism;

const REQUESTS: usize = 96;

/// One closed-loop run: submit every request up front, wait for all
/// replies, report wall seconds and the metrics ledger.
fn run_once(replicas: usize, max_batch: usize) -> (f64, Arc<Metrics>) {
    let engines: Vec<Arc<dyn EngineReplica>> = (0..replicas)
        .map(|_| {
            Arc::new(FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap())
                as Arc<dyn EngineReplica>
        })
        .collect();
    let m = engines[0].seq_len();
    let metrics = Arc::new(Metrics::new());
    let policy = BatchPolicy { max_batch, max_wait: Duration::from_micros(500) };
    let router = Router::start(engines, policy, Arc::clone(&metrics));

    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..REQUESTS)
        .map(|_| {
            let tokens: Vec<i32> = (0..m).map(|_| rng.below(60) as i32).collect();
            let (tx, rx) = channel();
            router.submit(tokens, tx);
            rx
        })
        .collect();
    for rx in receivers {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let wall = t0.elapsed().as_secs_f64();
    router.shutdown();
    (wall, metrics)
}

fn main() {
    println!(
        "serving-scaling sweep: {REQUESTS} closed-loop requests, tiny preset, \
         functional replicas (host parallelism {})",
        default_parallelism()
    );

    // warm up allocators / thread spawning before timing
    run_once(1, 8);

    let replica_counts = [1usize, 2, 4];
    let batch_sizes = [1usize, 4, 8, 16];
    let mut table = Table::new(&[
        "replicas", "max_batch", "wall", "req/s", "speedup", "virtual ms/replica",
    ]);
    let mut baseline: Vec<f64> = Vec::new(); // req/s at 1 replica, per batch size
    for &r in &replica_counts {
        for (bi, &b) in batch_sizes.iter().enumerate() {
            let (wall, metrics) = run_once(r, b);
            let rps = REQUESTS as f64 / wall;
            if r == 1 {
                baseline.push(rps);
            }
            let speedup = rps / baseline[bi];
            let virt_per_replica = metrics.total_accel_ms() / r as f64;
            table.row(&[
                r.to_string(),
                b.to_string(),
                fmt_time(wall),
                format!("{rps:.0}"),
                format!("{speedup:.2}x"),
                format!("{virt_per_replica:.2}"),
            ]);
        }
    }
    table.print("replica count x dispatch-group size (tiny preset)");
    println!(
        "\nspeedup column is vs the single-replica path at the same group size;\n\
         >1.0x for multi-replica rows demonstrates the pool converts replicas\n\
         into request throughput.  virtual ms/replica is simulated accelerator\n\
         time and stays constant per request — wall time drops, cycle cost\n\
         does not (the hardware claim the coordinator preserves)."
    );

    // --- kernel leg: serial vs row-tiled parallel i_matmul -------------
    let (m, k, n) = (256, 768, 768); // roberta_base projection shape
    let mut rng = Rng::new(2);
    let x: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let w: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i32).collect();
    let mut out = vec![0i32; m * n];
    let serial =
        Bench::new("i_matmul serial 256x768x768").iters(12).run(|| {
            i_matmul(&x, &w, None, m, k, n, &mut out);
            out[0]
        });
    let threads = default_parallelism();
    let tiled = Bench::new("i_matmul tiled  256x768x768")
        .iters(12)
        .run(|| {
            i_matmul_tiled(threads, &x, &w, None, m, k, n, &mut out);
            out[0]
        });
    println!(
        "kernel speedup {:.2}x with {threads} threads (bit-exact; threshold PAR_MIN_MACS gates the auto path)",
        serial.p50() / tiled.p50()
    );
}
