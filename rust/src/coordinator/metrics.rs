//! Serving metrics: aggregate counters + latency series shared across
//! the pool, per-replica accounting that pairs each simulated
//! accelerator's *virtual* time (cycles at the modeled clock) with the
//! wall-clock time its host thread actually spent, and — with several
//! resident models (DESIGN.md §8) — a per-model ledger so token volume,
//! padding waste, and virtual time are never blended across geometries
//! of very different `m`.
//!
//! With the concurrent per-group pipeline and SLO-aware autoscaling
//! (DESIGN.md §9) each model ledger additionally carries the signals
//! the autoscaler consumes and the per-tenant truth operators read:
//! live backlog (submitted minus settled), per-model end-to-end and
//! execution latency series (p50/p99 per tenant — a blended global p99
//! hides a heavy model's tail behind a cheap model's volume), the
//! active-replica gauge, and scale-up/-down counters.

use crate::util::stats::Series;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One replica's ledger.  `busy_ns` is host wall-clock execution time;
/// `accel_cycles`/`accel_ms` are the simulated accelerator's virtual
/// time for the same requests.
#[derive(Default)]
pub struct ReplicaStats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    /// wall-clock execution time, nanoseconds
    pub busy_ns: AtomicU64,
    /// simulated accelerator cycles across served requests
    pub accel_cycles: AtomicU64,
    /// simulated accelerator milliseconds (virtual time)
    accel_ms: Mutex<f64>,
}

impl ReplicaStats {
    /// Wall-clock seconds this replica spent executing requests.
    pub fn busy_s(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Virtual accelerator milliseconds accumulated by this replica.
    pub fn accel_ms(&self) -> f64 {
        *self.accel_ms.lock().unwrap()
    }
}

/// One model's ledger.  Submission-side token counts (`actual_tokens`,
/// `padded_tokens`) feed the per-model padding-waste metric; the
/// served-side counts (`served_tokens`, `served_padded_tokens`) are the
/// weighted-fair scheduler's currency, so their cross-model shares are
/// what converges to the configured weights under backlog.
#[derive(Default)]
pub struct ModelStats {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// live tokens submitted (serveable requests only)
    pub actual_tokens: AtomicU64,
    /// tokens after rounding each submitted request up to its dispatch
    /// bucket boundary
    pub padded_tokens: AtomicU64,
    /// live tokens of completed requests
    pub served_tokens: AtomicU64,
    /// bucket-padded tokens of completed requests (dispatch charge)
    pub served_padded_tokens: AtomicU64,
    /// simulated accelerator cycles across this model's requests
    pub accel_cycles: AtomicU64,
    /// simulated accelerator milliseconds (virtual time)
    accel_ms: Mutex<f64>,
    /// live backlog gauge: requests submitted but not yet completed or
    /// errored (queued + in flight) — the autoscaler's demand signal
    pub backlog: AtomicU64,
    /// live backlog in predicted cost units (`CostModel` cycles on the
    /// serving path): the sum of the predicted costs of every request
    /// submitted but not yet settled.  The autoscaler's *work* signal —
    /// unlike the request-count gauge it already knows a roberta_base
    /// backlog outweighs a tiny backlog (DESIGN.md §12)
    pub backlog_cost: AtomicU64,
    /// predicted cost of completed (non-error) requests — the
    /// denominator of the measured ms-per-cost calibration
    pub served_cost: AtomicU64,
    /// end-to-end wallclock latency per completed request (seconds) —
    /// the per-model p50/p99 ledger the SLO is judged against
    pub e2e_s: Mutex<Series>,
    /// execution wallclock per completed request (seconds)
    pub exec_s: Mutex<Series>,
    /// running sum of execution nanoseconds (with `completed` this
    /// gives the autoscaler an O(1), lock-free mean — the control loop
    /// ticks every few ms and must not scan the full latency series
    /// under the serving path's mutex)
    exec_ns_total: AtomicU64,
    /// active replicas currently serving this model (autoscaler gauge)
    pub replicas: AtomicU64,
    /// replica grow events applied by the autoscaler
    pub scale_ups: AtomicU64,
    /// replica drain-then-retire events applied by the autoscaler
    pub scale_downs: AtomicU64,
    /// replica panics observed while serving this model (each retires
    /// the faulted slot when the group can respawn — the chaos legs'
    /// fault gauge)
    pub replica_faults: AtomicU64,
    /// requests re-served on another replica after their first replica
    /// panicked (the zero-loss recovery path)
    pub retries: AtomicU64,
    /// requests rejected at admission with a typed `Overloaded`
    /// response because the model's predicted queueing delay exceeded
    /// its SLO (DESIGN.md §11) — shed requests never enter the queue,
    /// so they appear here and nowhere else
    pub shed: AtomicU64,
    /// requests this tier answered below its confidence gate and handed
    /// to its escalation sibling instead of serving (DESIGN.md §14).
    /// Only front (INT4) tiers of a cascade pair ever move this; the
    /// tier's cycles for the attempt still land on `served_cost` /
    /// `accel_cycles`, so the per-precision served-cost ledgers price
    /// the escalation surcharge honestly.
    pub escalated: AtomicU64,
    /// the tier's live logit-margin escalation threshold (the per-tenant
    /// cascade knob; 0 = no gate)
    pub escalate_margin: AtomicU64,
}

impl ModelStats {
    /// Fraction of this model's bucket-padded submitted tokens that
    /// carry no live data: `(padded - actual) / padded`.  Counted per
    /// model — a single global pair would silently blend models of very
    /// different `m` (the ISSUE 4 regression).
    pub fn padding_waste(&self) -> f64 {
        let actual = self.actual_tokens.load(Ordering::Relaxed);
        let padded = self.padded_tokens.load(Ordering::Relaxed);
        if padded == 0 {
            0.0
        } else {
            (padded.saturating_sub(actual)) as f64 / padded as f64
        }
    }

    /// Virtual accelerator milliseconds accumulated for this model.
    pub fn accel_ms(&self) -> f64 {
        *self.accel_ms.lock().unwrap()
    }

    /// p50/p99 end-to-end latency in milliseconds (NaN with no
    /// completions yet).
    pub fn e2e_percentiles_ms(&self) -> (f64, f64) {
        let s = self.e2e_s.lock().unwrap();
        (s.p50() * 1e3, s.p99() * 1e3)
    }

    /// Mean execution wall milliseconds per completed request, or
    /// `fallback_ms` before the first completion — the autoscaler's
    /// service-time estimate.  O(1) off the running counters (no lock,
    /// no series scan): this runs on every control-loop tick.
    pub fn mean_exec_ms(&self, fallback_ms: f64) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            fallback_ms
        } else {
            self.exec_ns_total.load(Ordering::Relaxed) as f64 / n as f64 / 1e6
        }
    }

    /// Measured wall milliseconds per unit of predicted cost —
    /// `Σ exec_ms / Σ served_cost` — or `None` before the first
    /// completion.  Multiplied by [`ModelStats::backlog_cost`] this
    /// turns the predicted-work backlog into a wall-clock drain
    /// estimate calibrated to the host actually serving it; callers
    /// with a [`crate::sim::CostModel`] in hand fall back to its
    /// virtual clock before the first sample lands (DESIGN.md §12).
    /// O(1) off the running counters, like [`ModelStats::mean_exec_ms`].
    pub fn ms_per_cost(&self) -> Option<f64> {
        let cost = self.served_cost.load(Ordering::Relaxed);
        if cost == 0 {
            None
        } else {
            Some(self.exec_ns_total.load(Ordering::Relaxed) as f64 / 1e6 / cost as f64)
        }
    }
}

/// Name + fair-share weight + stats of one registered model.
struct ModelLedger {
    name: String,
    weight: u64,
    stats: Arc<ModelStats>,
}

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// live tokens submitted across all models (the sum of request
    /// sequence lengths)
    pub actual_tokens: AtomicU64,
    /// tokens after rounding each request up to its dispatch bucket
    /// boundary — what a bucket-configured accelerator would process
    pub padded_tokens: AtomicU64,
    /// end-to-end wallclock latency (seconds)
    pub e2e_s: Mutex<Series>,
    /// time spent queued before dispatch (seconds)
    pub queue_s: Mutex<Series>,
    /// execution wallclock (seconds)
    pub exec_s: Mutex<Series>,
    /// simulated accelerator time (milliseconds of virtual time)
    pub accel_ms: Mutex<Series>,
    /// per-replica ledgers, sized by the pool at startup
    replicas: Mutex<Vec<Arc<ReplicaStats>>>,
    /// per-model ledgers, registered by the router at startup
    models: Mutex<Vec<ModelLedger>>,
    /// connections currently open at the front door (gauge)
    pub conns_open: AtomicU64,
    /// connections accepted since startup
    pub conns_accepted: AtomicU64,
    /// connections refused at the cap with a typed `busy` rejection
    pub conns_rejected: AtomicU64,
    /// executor worker threads in the router's global core budget
    /// (gauge; 0 until a pool is built — DESIGN.md §13)
    pub core_budget: AtomicU64,
    /// end-to-end wallclock latency of *escalated* requests (seconds),
    /// measured from original submission to the INT8 tier's reply —
    /// the cascade's two-hop tail (p50/p99 in the report; DESIGN.md
    /// §14)
    pub cascade_e2e_s: Mutex<Series>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Size the per-replica ledger (idempotent; only ever grows).
    pub fn ensure_replicas(&self, n: usize) {
        let mut r = self.replicas.lock().unwrap();
        while r.len() < n {
            r.push(Arc::new(ReplicaStats::default()));
        }
    }

    /// Ledger of replica `i` (created on demand).
    pub fn replica(&self, i: usize) -> Arc<ReplicaStats> {
        let mut r = self.replicas.lock().unwrap();
        while r.len() <= i {
            r.push(Arc::new(ReplicaStats::default()));
        }
        Arc::clone(&r[i])
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.lock().unwrap().len()
    }

    /// Register the model ledger (idempotent; index = model id).  A
    /// name/weight registered here overrides the on-demand placeholder.
    pub fn ensure_models(&self, specs: &[(&str, u64)]) {
        let mut m = self.models.lock().unwrap();
        for (i, &(name, weight)) in specs.iter().enumerate() {
            if m.len() <= i {
                m.push(ModelLedger {
                    name: name.to_string(),
                    weight: weight.max(1),
                    stats: Arc::new(ModelStats::default()),
                });
            } else {
                m[i].name = name.to_string();
                m[i].weight = weight.max(1);
            }
        }
    }

    /// Ledger of model `i` (created on demand with a placeholder name).
    pub fn model(&self, i: usize) -> Arc<ModelStats> {
        let mut m = self.models.lock().unwrap();
        while m.len() <= i {
            let name = format!("model{}", m.len());
            m.push(ModelLedger { name, weight: 1, stats: Arc::new(ModelStats::default()) });
        }
        Arc::clone(&m[i].stats)
    }

    pub fn model_count(&self) -> usize {
        self.models.lock().unwrap().len()
    }

    pub fn model_name(&self, i: usize) -> Option<String> {
        self.models.lock().unwrap().get(i).map(|m| m.name.clone())
    }

    /// Model `i`'s share of all served bucket-padded tokens — the
    /// quantity the weighted-fair dispatcher drives toward
    /// `weight_i / Σ weights` while every model stays backlogged.
    pub fn model_token_share(&self, i: usize) -> f64 {
        let m = self.models.lock().unwrap();
        let total: u64 =
            m.iter().map(|l| l.stats.served_padded_tokens.load(Ordering::Relaxed)).sum();
        match m.get(i) {
            Some(l) if total > 0 => {
                l.stats.served_padded_tokens.load(Ordering::Relaxed) as f64 / total as f64
            }
            _ => 0.0,
        }
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one submitted request of predicted cost `cost` against
    /// model `i`'s ledger as well as the aggregate counter.  Raises the
    /// model's live backlog gauges (request count AND predicted work);
    /// [`Metrics::record_model_served`] settles both.  The serving path
    /// passes `CostModel::predict_cycles(len)`; cost-agnostic callers
    /// pass the padded token count so the work gauge still moves.
    pub fn record_request_for(&self, model: usize, cost: u64) {
        self.record_request();
        let m = self.model(model);
        m.requests.fetch_add(1, Ordering::Relaxed);
        m.backlog.fetch_add(1, Ordering::Relaxed);
        m.backlog_cost.fetch_add(cost, Ordering::Relaxed);
    }

    /// Account one request's live token count and the padded count its
    /// dispatch bucket charges (equal when bucketing is off), per model
    /// and in aggregate.
    pub fn record_tokens(&self, model: usize, actual: usize, padded: usize) {
        self.actual_tokens.fetch_add(actual as u64, Ordering::Relaxed);
        self.padded_tokens.fetch_add(padded as u64, Ordering::Relaxed);
        let m = self.model(model);
        m.actual_tokens.fetch_add(actual as u64, Ordering::Relaxed);
        m.padded_tokens.fetch_add(padded as u64, Ordering::Relaxed);
    }

    /// Fraction of bucket-padded tokens that carry no live data across
    /// *all* models: `(padded - actual) / padded`.  0 when bucketing is
    /// off or nothing was submitted.  With models of different `m`
    /// resident this blend is dominated by whichever model moved the
    /// most tokens — read [`ModelStats::padding_waste`] for the
    /// per-model truth.
    pub fn padding_waste(&self) -> f64 {
        let actual = self.actual_tokens.load(Ordering::Relaxed);
        let padded = self.padded_tokens.load(Ordering::Relaxed);
        if padded == 0 {
            0.0
        } else {
            (padded.saturating_sub(actual)) as f64 / padded as f64
        }
    }

    pub fn record_completion(&self, e2e: f64, queued: f64, exec: f64, accel_ms: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.e2e_s.lock().unwrap().push(e2e);
        self.queue_s.lock().unwrap().push(queued);
        self.exec_s.lock().unwrap().push(exec);
        self.accel_ms.lock().unwrap().push(accel_ms);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one served request against replica `i`'s ledger.
    pub fn record_replica(&self, i: usize, exec_s: f64, cycles: u64, accel_ms: f64, error: bool) {
        let r = self.replica(i);
        r.requests.fetch_add(1, Ordering::Relaxed);
        if error {
            r.errors.fetch_add(1, Ordering::Relaxed);
        }
        r.busy_ns.fetch_add((exec_s * 1e9) as u64, Ordering::Relaxed);
        r.accel_cycles.fetch_add(cycles, Ordering::Relaxed);
        *r.accel_ms.lock().unwrap() += accel_ms;
    }

    /// Account one completed (or failed) request against model `i`'s
    /// ledger: the live and bucket-padded tokens actually served, the
    /// predicted cost its submission charged (settling the work gauge
    /// and — on success — calibrating ms-per-cost), the virtual
    /// accelerator time it cost, and the wall-clock end-to-end /
    /// execution latencies feeding the per-model p50/p99 ledgers.
    /// Settles the live backlog gauges either way; errors skip the
    /// latency series (a typed rejection is near-instant and would
    /// deflate the tail).
    #[allow(clippy::too_many_arguments)]
    pub fn record_model_served(
        &self,
        model: usize,
        actual: usize,
        padded: usize,
        cost: u64,
        cycles: u64,
        accel_ms: f64,
        e2e_s: f64,
        exec_s: f64,
        error: bool,
    ) {
        let m = self.model(model);
        let b = &m.backlog;
        let _ = b.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
        // saturating settle: mock-driven tests settle without a
        // matching submit, and the gauge must never wrap
        let _ = m.backlog_cost.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(cost))
        });
        if error {
            m.errors.fetch_add(1, Ordering::Relaxed);
            return;
        }
        m.completed.fetch_add(1, Ordering::Relaxed);
        m.served_tokens.fetch_add(actual as u64, Ordering::Relaxed);
        m.served_padded_tokens.fetch_add(padded as u64, Ordering::Relaxed);
        m.served_cost.fetch_add(cost, Ordering::Relaxed);
        m.accel_cycles.fetch_add(cycles, Ordering::Relaxed);
        *m.accel_ms.lock().unwrap() += accel_ms;
        m.e2e_s.lock().unwrap().push(e2e_s);
        m.exec_s.lock().unwrap().push(exec_s);
        m.exec_ns_total.fetch_add((exec_s * 1e9) as u64, Ordering::Relaxed);
    }

    /// Update model `i`'s active-replica gauge (the per-group runtime
    /// calls this at startup and on every autoscaler grow/shrink).
    pub fn set_model_replicas(&self, model: usize, n: usize) {
        self.model(model).replicas.store(n as u64, Ordering::Relaxed);
    }

    /// Record the size of the global executor core budget (the replica
    /// pool sets this once at construction; DESIGN.md §13).
    pub fn set_core_budget(&self, n: usize) {
        self.core_budget.store(n as u64, Ordering::Relaxed);
    }

    /// Count one replica panic against model `i` (the faulted slot's
    /// retirement shows up in the replica gauge, not here).
    pub fn record_fault(&self, model: usize) {
        self.model(model).replica_faults.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one post-panic retry for model `i`.
    pub fn record_retry(&self, model: usize) {
        self.model(model).retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one admission-control rejection (typed `Overloaded`
    /// response) against model `i`.  Shed requests bypass the queue
    /// entirely: no request/backlog/latency accounting, only this.
    pub fn record_shed(&self, model: usize) {
        self.model(model).shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one cascade escalation against front tier `model`
    /// (DESIGN.md §14): the request leaves this tier's backlog gauges
    /// (it re-enters its sibling's at re-submission) without counting
    /// as a completion — no reply went out and no end-to-end latency
    /// exists yet.  The INT4 attempt's real work *is* settled here:
    /// its predicted cost joins `served_cost`, its virtual time joins
    /// `accel_cycles`/`accel_ms`, and its wall execution time keeps the
    /// tier's ms-per-cost calibration honest — that surcharge is
    /// exactly what the cascade's cycles/request ledger must show.
    pub fn record_escalated(
        &self,
        model: usize,
        cost: u64,
        cycles: u64,
        accel_ms: f64,
        exec_s: f64,
    ) {
        let m = self.model(model);
        let _ = m.backlog.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            v.checked_sub(1)
        });
        let _ = m.backlog_cost.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(cost))
        });
        m.escalated.fetch_add(1, Ordering::Relaxed);
        m.served_cost.fetch_add(cost, Ordering::Relaxed);
        m.accel_cycles.fetch_add(cycles, Ordering::Relaxed);
        *m.accel_ms.lock().unwrap() += accel_ms;
        m.exec_ns_total.fetch_add((exec_s * 1e9) as u64, Ordering::Relaxed);
    }

    /// Account an escalated request re-entering the queue bound for
    /// tier `model` (its new predicted cost at that tier's precision).
    /// Unlike [`Metrics::record_request_for`] the aggregate request
    /// counter stays put — the client submitted once; the cascade hop
    /// is internal traffic.
    pub fn record_reenqueued(&self, model: usize, cost: u64) {
        let m = self.model(model);
        m.requests.fetch_add(1, Ordering::Relaxed);
        m.backlog.fetch_add(1, Ordering::Relaxed);
        m.backlog_cost.fetch_add(cost, Ordering::Relaxed);
    }

    /// Publish front tier `model`'s live escalation threshold (the
    /// per-tenant cascade knob surfaced in the report).
    pub fn set_escalate_margin(&self, model: usize, margin: i64) {
        self.model(model).escalate_margin.store(margin.max(0) as u64, Ordering::Relaxed);
    }

    /// Record the two-hop end-to-end latency of an escalated request
    /// (original submission to INT8 reply).
    pub fn record_cascade_e2e(&self, e2e_s: f64) {
        self.cascade_e2e_s.lock().unwrap().push(e2e_s);
    }

    /// Account one accepted front-door connection (raises the open
    /// gauge; [`Metrics::record_conn_closed`] settles it).
    pub fn record_conn_opened(&self) {
        self.conns_open.fetch_add(1, Ordering::Relaxed);
        self.conns_accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// Settle the open-connection gauge (saturating, never wraps).
    pub fn record_conn_closed(&self) {
        let _ = self.conns_open.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            v.checked_sub(1)
        });
    }

    /// Count one connection refused at the cap with a typed `busy`
    /// rejection.
    pub fn record_conn_rejected(&self) {
        self.conns_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one applied autoscaler action for model `i`.
    pub fn record_scale(&self, model: usize, up: bool) {
        let m = self.model(model);
        if up {
            m.scale_ups.fetch_add(1, Ordering::Relaxed);
        } else {
            m.scale_downs.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Virtual accelerator milliseconds summed over all replicas.
    pub fn total_accel_ms(&self) -> f64 {
        self.replicas.lock().unwrap().iter().map(|r| r.accel_ms()).sum()
    }

    pub fn report(&self) -> String {
        let done = self.completed.load(Ordering::Relaxed);
        let req = self.requests.load(Ordering::Relaxed);
        let err = self.errors.load(Ordering::Relaxed);
        let mut out = format!(
            "requests={req} completed={done} errors={err}\n  e2e   {}\n  queue {}\n  exec  {}\n  accel {}\n  tokens actual={} padded={} waste={:.1}%",
            self.e2e_s.lock().unwrap().summary("s"),
            self.queue_s.lock().unwrap().summary("s"),
            self.exec_s.lock().unwrap().summary("s"),
            self.accel_ms.lock().unwrap().summary("ms"),
            self.actual_tokens.load(Ordering::Relaxed),
            self.padded_tokens.load(Ordering::Relaxed),
            100.0 * self.padding_waste(),
        );
        out.push_str(&format!(
            "\n  conns open={} accepted={} rejected={}",
            self.conns_open.load(Ordering::Relaxed),
            self.conns_accepted.load(Ordering::Relaxed),
            self.conns_rejected.load(Ordering::Relaxed),
        ));
        out.push_str(&format!(
            "\n  cores budget={}",
            self.core_budget.load(Ordering::Relaxed),
        ));
        {
            let cascade = self.cascade_e2e_s.lock().unwrap();
            if cascade.len() > 0 {
                out.push_str(&format!("\n  cascade e2e {}", cascade.summary("s")));
            }
        }
        {
            let models = self.models.lock().unwrap();
            let total_w: u64 = models.iter().map(|l| l.weight).sum();
            let total_served: u64 =
                models.iter().map(|l| l.stats.served_padded_tokens.load(Ordering::Relaxed)).sum();
            for l in models.iter() {
                let served = l.stats.served_padded_tokens.load(Ordering::Relaxed);
                let share = if total_served > 0 {
                    100.0 * served as f64 / total_served as f64
                } else {
                    0.0
                };
                let weight_pct = if total_w > 0 {
                    100.0 * l.weight as f64 / total_w as f64
                } else {
                    0.0
                };
                let (p50_ms, p99_ms) = l.stats.e2e_percentiles_ms();
                out.push_str(&format!(
                    "\n  model {} (w={}): requests={} completed={} errors={} waste={:.1}% \
                     served tokens={} share={:.1}% (weight {:.1}%) virtual={:.3}ms \
                     backlog={} (work={}cy) served_work={}cy replicas={} \
                     e2e p50={p50_ms:.3}ms p99={p99_ms:.3}ms \
                     scale +{}/-{} faults={} retried={} shed={}",
                    l.name,
                    l.weight,
                    l.stats.requests.load(Ordering::Relaxed),
                    l.stats.completed.load(Ordering::Relaxed),
                    l.stats.errors.load(Ordering::Relaxed),
                    100.0 * l.stats.padding_waste(),
                    served,
                    share,
                    weight_pct,
                    l.stats.accel_ms(),
                    l.stats.backlog.load(Ordering::Relaxed),
                    l.stats.backlog_cost.load(Ordering::Relaxed),
                    l.stats.served_cost.load(Ordering::Relaxed),
                    l.stats.replicas.load(Ordering::Relaxed),
                    l.stats.scale_ups.load(Ordering::Relaxed),
                    l.stats.scale_downs.load(Ordering::Relaxed),
                    l.stats.replica_faults.load(Ordering::Relaxed),
                    l.stats.retries.load(Ordering::Relaxed),
                    l.stats.shed.load(Ordering::Relaxed),
                ));
                let escalated = l.stats.escalated.load(Ordering::Relaxed);
                let margin = l.stats.escalate_margin.load(Ordering::Relaxed);
                if escalated > 0 || margin > 0 {
                    let reqs = l.stats.requests.load(Ordering::Relaxed);
                    let rate = if reqs > 0 {
                        100.0 * escalated as f64 / reqs as f64
                    } else {
                        0.0
                    };
                    out.push_str(&format!(
                        " escalated={escalated} ({rate:.1}%) margin={margin}"
                    ));
                }
            }
        }
        for (i, r) in self.replicas.lock().unwrap().iter().enumerate() {
            out.push_str(&format!(
                "\n  replica {i}: requests={} errors={} busy={:.3}s virtual={:.3}ms ({} cycles)",
                r.requests.load(Ordering::Relaxed),
                r.errors.load(Ordering::Relaxed),
                r.busy_s(),
                r.accel_ms(),
                r.accel_cycles.load(Ordering::Relaxed),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_series_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_completion(0.01, 0.001, 0.008, 0.03);
        m.record_error();
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("completed=1"));
    }

    #[test]
    fn replica_ledgers_track_virtual_and_wall_time() {
        let m = Metrics::new();
        m.ensure_replicas(2);
        assert_eq!(m.replica_count(), 2);
        m.record_replica(0, 0.002, 1_000, 0.007, false);
        m.record_replica(0, 0.002, 1_000, 0.007, false);
        m.record_replica(1, 0.004, 2_000, 0.014, true);
        let r0 = m.replica(0);
        assert_eq!(r0.requests.load(Ordering::Relaxed), 2);
        assert_eq!(r0.accel_cycles.load(Ordering::Relaxed), 2_000);
        assert!((r0.busy_s() - 0.004).abs() < 1e-9);
        assert!((r0.accel_ms() - 0.014).abs() < 1e-12);
        assert_eq!(m.replica(1).errors.load(Ordering::Relaxed), 1);
        assert!((m.total_accel_ms() - 0.028).abs() < 1e-12);
        let report = m.report();
        assert!(report.contains("replica 0:"));
        assert!(report.contains("replica 1:"));
    }

    #[test]
    fn padding_waste_tracks_bucket_overhead() {
        let m = Metrics::new();
        assert_eq!(m.padding_waste(), 0.0, "no traffic, no waste");
        m.record_tokens(0, 3, 8);
        m.record_tokens(0, 5, 8);
        m.record_tokens(0, 16, 16);
        assert_eq!(m.actual_tokens.load(Ordering::Relaxed), 24);
        assert_eq!(m.padded_tokens.load(Ordering::Relaxed), 32);
        assert!((m.padding_waste() - 0.25).abs() < 1e-12);
        assert!(m.report().contains("waste=25.0%"));
    }

    #[test]
    fn padding_waste_is_counted_per_model_not_blended() {
        // Regression (ISSUE 4): a short-sequence model at 50% bucket
        // waste next to a long-sequence model at 0% used to blend into
        // one global pair dominated by whichever moved more tokens.
        // The per-model ledgers keep the truth; the global number stays
        // as the (documented) blend.
        let m = Metrics::new();
        m.ensure_models(&[("tiny", 1), ("roberta_base", 1)]);
        m.record_tokens(0, 3, 8);
        m.record_tokens(0, 5, 8);
        m.record_tokens(1, 256, 256);
        let tiny = m.model(0);
        let base = m.model(1);
        assert!((tiny.padding_waste() - 0.5).abs() < 1e-12, "tiny wastes half its buckets");
        assert_eq!(base.padding_waste(), 0.0, "full-length model pads nothing");
        // the blended global figure under-reports tiny's waste 16x
        let blended = m.padding_waste();
        assert!((blended - 8.0 / 272.0).abs() < 1e-12, "blended={blended}");
        let report = m.report();
        assert!(report.contains("model tiny"), "{report}");
        assert!(report.contains("waste=50.0%"), "{report}");
    }

    #[test]
    fn model_ledgers_track_served_shares() {
        let m = Metrics::new();
        m.ensure_models(&[("a", 3), ("b", 1)]);
        m.record_request_for(0, 100);
        m.record_request_for(1, 50);
        m.record_model_served(0, 8, 8, 100, 100, 0.7, 0.010, 0.004, false);
        m.record_model_served(0, 8, 8, 100, 100, 0.7, 0.020, 0.005, false);
        m.record_model_served(0, 8, 8, 100, 100, 0.7, 0.030, 0.006, false);
        m.record_model_served(1, 4, 8, 50, 50, 0.3, 0.010, 0.002, false);
        m.record_model_served(1, 2, 0, 0, 0, 0.0, 0.0, 0.0, true); // error: no tokens served
        let a = m.model(0);
        let b = m.model(1);
        assert_eq!(a.completed.load(Ordering::Relaxed), 3);
        assert_eq!(b.completed.load(Ordering::Relaxed), 1);
        assert_eq!(b.errors.load(Ordering::Relaxed), 1);
        assert_eq!(a.served_padded_tokens.load(Ordering::Relaxed), 24);
        assert_eq!(b.served_padded_tokens.load(Ordering::Relaxed), 8);
        assert!((m.model_token_share(0) - 0.75).abs() < 1e-12);
        assert!((m.model_token_share(1) - 0.25).abs() < 1e-12);
        assert!((a.accel_ms() - 2.1).abs() < 1e-12);
        assert_eq!(m.model_name(0).as_deref(), Some("a"));
        // per-model latency ledger: p50/p99 over this model's own
        // completions only, errors excluded
        let (p50, p99) = a.e2e_percentiles_ms();
        assert!((p50 - 20.0).abs() < 1e-9, "p50={p50}");
        assert!((p99 - 30.0).abs() < 1e-9, "p99={p99}");
        assert!((a.mean_exec_ms(99.0) - 5.0).abs() < 1e-9);
        assert_eq!(b.e2e_s.lock().unwrap().len(), 1, "error skipped the latency series");
        let report = m.report();
        assert!(report.contains("model a (w=3)"), "{report}");
        assert!(report.contains("share=75.0%"), "{report}");
        assert!(report.contains("p99="), "{report}");
    }

    #[test]
    fn backlog_gauge_tracks_submitted_minus_settled() {
        let m = Metrics::new();
        m.ensure_models(&[("a", 1)]);
        m.record_request_for(0, 500);
        m.record_request_for(0, 500);
        m.record_request_for(0, 500);
        assert_eq!(m.model(0).backlog.load(Ordering::Relaxed), 3);
        assert_eq!(m.model(0).backlog_cost.load(Ordering::Relaxed), 1500);
        m.record_model_served(0, 4, 8, 500, 10, 0.1, 0.001, 0.001, false);
        m.record_model_served(0, 0, 0, 500, 0, 0.0, 0.0, 0.0, true); // errors settle too
        assert_eq!(m.model(0).backlog.load(Ordering::Relaxed), 1);
        assert_eq!(m.model(0).backlog_cost.load(Ordering::Relaxed), 500);
        // a settle without a matching submit saturates at zero instead
        // of wrapping (mock-driven tests bypass record_request_for)
        m.record_model_served(0, 4, 8, 500, 10, 0.1, 0.001, 0.001, false);
        m.record_model_served(0, 4, 8, 500, 10, 0.1, 0.001, 0.001, false);
        assert_eq!(m.model(0).backlog.load(Ordering::Relaxed), 0);
        assert_eq!(m.model(0).backlog_cost.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn cost_ledger_calibrates_ms_per_cost() {
        let m = Metrics::new();
        m.ensure_models(&[("a", 1)]);
        assert_eq!(m.model(0).ms_per_cost(), None, "no completions, no calibration");
        m.record_request_for(0, 1000);
        m.record_request_for(0, 1000);
        // two completions at 2 ms exec each over 2000 cost units
        m.record_model_served(0, 8, 8, 1000, 1000, 0.7, 0.003, 0.002, false);
        m.record_model_served(0, 8, 8, 1000, 1000, 0.7, 0.003, 0.002, false);
        let a = m.model(0);
        assert_eq!(a.served_cost.load(Ordering::Relaxed), 2000);
        let mpc = a.ms_per_cost().unwrap();
        assert!((mpc - 0.002).abs() < 1e-9, "4 ms over 2000 cost = 0.002 ms/cost, got {mpc}");
        // errors contribute neither cost nor exec time to calibration
        m.record_model_served(0, 0, 0, 500, 0, 0.0, 0.0, 0.0, true);
        assert_eq!(a.served_cost.load(Ordering::Relaxed), 2000);
        assert!(m.report().contains("served_work=2000cy"), "{}", m.report());
    }

    #[test]
    fn replica_gauge_and_scale_counters() {
        let m = Metrics::new();
        m.ensure_models(&[("a", 1)]);
        m.set_model_replicas(0, 2);
        assert_eq!(m.model(0).replicas.load(Ordering::Relaxed), 2);
        m.record_scale(0, true);
        m.record_scale(0, true);
        m.record_scale(0, false);
        assert_eq!(m.model(0).scale_ups.load(Ordering::Relaxed), 2);
        assert_eq!(m.model(0).scale_downs.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("scale +2/-1"), "{}", m.report());
    }

    #[test]
    fn core_budget_gauge_surfaces_in_report() {
        let m = Metrics::new();
        m.set_core_budget(6);
        assert!(m.report().contains("cores budget=6"), "{}", m.report());
    }

    #[test]
    fn shed_and_connection_counters_surface_in_report() {
        let m = Metrics::new();
        m.ensure_models(&[("a", 1)]);
        m.record_shed(0);
        m.record_shed(0);
        m.record_conn_opened();
        m.record_conn_opened();
        m.record_conn_rejected();
        m.record_conn_closed();
        assert_eq!(m.model(0).shed.load(Ordering::Relaxed), 2);
        let report = m.report();
        assert!(report.contains("shed=2"), "{report}");
        assert!(report.contains("conns open=1 accepted=2 rejected=1"), "{report}");
        // close never wraps below zero
        m.record_conn_closed();
        m.record_conn_closed();
        assert_eq!(m.conns_open.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn escalation_ledger_settles_backlog_without_counting_completion() {
        let m = Metrics::new();
        m.ensure_models(&[("front", 1), ("front@int8", 1)]);
        m.set_escalate_margin(0, 6000);
        m.record_request_for(0, 100);
        m.record_request_for(0, 100);
        // one request serves at the front tier, one escalates
        m.record_model_served(0, 8, 8, 100, 100, 0.7, 0.010, 0.004, false);
        m.record_escalated(0, 100, 100, 0.7, 0.004);
        let front = m.model(0);
        assert_eq!(front.backlog.load(Ordering::Relaxed), 0, "escalation settles backlog");
        assert_eq!(front.backlog_cost.load(Ordering::Relaxed), 0);
        assert_eq!(front.completed.load(Ordering::Relaxed), 1, "escalation is not a completion");
        assert_eq!(front.escalated.load(Ordering::Relaxed), 1);
        assert_eq!(
            front.served_cost.load(Ordering::Relaxed),
            200,
            "the INT4 attempt's cycles stay on the front tier's served-cost ledger"
        );
        assert_eq!(front.accel_cycles.load(Ordering::Relaxed), 200);
        assert_eq!(front.e2e_s.lock().unwrap().len(), 1, "no e2e sample for the escalation");
        // the two-hop latency lands on the cascade series at INT8 completion
        m.record_request_for(1, 400);
        m.record_model_served(1, 8, 8, 400, 400, 2.8, 0.025, 0.012, false);
        m.record_cascade_e2e(0.025);
        assert_eq!(m.cascade_e2e_s.lock().unwrap().len(), 1);
        let report = m.report();
        assert!(report.contains("escalated=1 (50.0%) margin=6000"), "{report}");
        assert!(report.contains("cascade e2e"), "{report}");
    }

    #[test]
    fn replica_and_model_ledgers_grow_on_demand() {
        let m = Metrics::new();
        m.record_replica(3, 0.001, 10, 0.0, false);
        assert_eq!(m.replica_count(), 4);
        assert_eq!(m.replica(3).requests.load(Ordering::Relaxed), 1);
        m.record_model_served(2, 1, 8, 1, 1, 0.0, 0.001, 0.001, false);
        assert_eq!(m.model_count(), 3);
        assert_eq!(m.model_name(2).as_deref(), Some("model2"));
        assert_eq!(m.model(2).completed.load(Ordering::Relaxed), 1);
    }
}
