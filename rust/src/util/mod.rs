//! In-repo substrates.
//!
//! The offline build environment only carries the `xla` crate's dependency
//! closure, so the conveniences a production service would pull from
//! crates.io (tokio, clap, serde, rand, proptest, criterion) are
//! implemented here from scratch — deliberately small, tested, and
//! sufficient for this system.

pub mod bench;
pub mod budget;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
