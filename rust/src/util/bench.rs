//! Bench harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses
//! [`Bench`] for warmup + timed repetitions and prints aligned tables —
//! one bench target per paper table/figure (DESIGN.md §4).

use super::json::Json;
use super::stats::Series;
use std::path::Path;
use std::time::Instant;

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // volatile read of the value's address: a stable-Rust black box without asm
    unsafe {
        let ptr = &x as *const T;
        std::ptr::read_volatile(&ptr);
    }
    x
}

pub struct Bench {
    name: String,
    warmup_iters: usize,
    iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench { name: name.to_string(), warmup_iters: 3, iters: 10 }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup_iters = n;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n;
        self
    }

    /// Time `f` and report per-iteration stats (seconds).
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> Series {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut s = Series::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            s.push(t0.elapsed().as_secs_f64());
        }
        println!(
            "bench {:40} mean {:>10} p50 {:>10} min {:>10}  (n={})",
            self.name,
            fmt_time(s.mean()),
            fmt_time(s.p50()),
            fmt_time(s.min()),
            s.len()
        );
        s
    }
}

/// Merge top-level keys into the JSON object at `path`
/// (read-modify-write): sibling bench binaries writing the same file
/// keep each other's legs instead of clobbering the whole object.  A
/// missing or unparsable file starts from an empty object.
///
/// When both the existing value and the update for a key are objects,
/// the update merges **recursively** instead of replacing the whole
/// object — so different binaries can each own a sub-leg at any depth
/// under a shared key (e.g. `costmodel.fairness` from `serving_scaling`
/// and `costmodel.design_space` from `table1_synthesis`, or the
/// cascade leg's `cascade.<margin>.<tenants>` sub-objects).  The
/// recursion stops at the first non-object on either side: a leaf
/// update always replaces the old value wholesale, so a leg still owns
/// its own payload.
pub fn merge_bench_json<P: AsRef<Path>>(
    path: P,
    updates: impl IntoIterator<Item = (&'static str, Json)>,
) -> Result<(), String> {
    fn merge_value(old: &mut Json, new: Json) {
        match (old, new) {
            (Json::Obj(old), Json::Obj(new)) => {
                for (k, v) in new {
                    match old.get_mut(&k) {
                        Some(slot) => merge_value(slot, v),
                        None => {
                            old.insert(k, v);
                        }
                    }
                }
            }
            (old, new) => *old = new,
        }
    }
    let path = path.as_ref();
    let mut root = Json::Obj(match std::fs::read_to_string(path) {
        Ok(src) => match Json::parse(&src) {
            Ok(Json::Obj(m)) => m,
            _ => Default::default(),
        },
        Err(_) => Default::default(),
    });
    for (k, v) in updates {
        merge_value(&mut root, Json::Obj(std::iter::once((k.to_string(), v)).collect()));
    }
    std::fs::write(path, format!("{root}\n"))
        .map_err(|e| format!("write {}: {e}", path.display()))
}

pub fn fmt_time(secs: f64) -> String {
    if secs.is_nan() {
        "-".into()
    } else if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Simple aligned-table printer used by the per-figure bench binaries.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("\n== {title} ==");
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_iters_samples() {
        let s = Bench::new("noop").warmup(1).iters(5).run(|| 1 + 1);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn merge_bench_json_preserves_sibling_keys() {
        let path = std::env::temp_dir()
            .join(format!("swifttron_merge_bench_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        merge_bench_json(&path, [("a", Json::from(1i64))]).unwrap();
        merge_bench_json(&path, [("b", Json::from("x"))]).unwrap();
        merge_bench_json(&path, [("a", Json::from(2i64))]).unwrap();
        let v = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(v["a"].as_i64(), Some(2), "re-run overwrites its own key");
        assert_eq!(v["b"].as_str(), Some("x"), "sibling key survives the re-run");
    }

    #[test]
    fn merge_bench_json_merges_shared_object_keys_recursively() {
        use super::super::json::obj;
        let path = std::env::temp_dir()
            .join(format!("swifttron_merge_nested_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        merge_bench_json(&path, [("shared", obj([("left", Json::from(1i64))]))]).unwrap();
        merge_bench_json(&path, [("shared", obj([("right", Json::from(2i64))]))]).unwrap();
        // a non-object update still replaces the object wholesale
        merge_bench_json(&path, [("flat", Json::from(3i64))]).unwrap();
        merge_bench_json(&path, [("flat", obj([("now_obj", Json::from(4i64))]))]).unwrap();
        let v = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(v["shared"]["left"].as_i64(), Some(1), "first sub-leg survives");
        assert_eq!(v["shared"]["right"].as_i64(), Some(2), "second sub-leg merged in");
        assert_eq!(v["flat"]["now_obj"].as_i64(), Some(4), "non-object old value replaced");
    }

    #[test]
    fn merge_bench_json_recurses_below_the_first_level() {
        // Two binaries each own a sub-leg *two* levels down the same
        // branch — the old one-level merge clobbered the sibling here.
        use super::super::json::obj;
        let path = std::env::temp_dir()
            .join(format!("swifttron_merge_deep_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        merge_bench_json(
            &path,
            [("top", obj([("mid", obj([("a", Json::from(1i64))])), ("keep", Json::from(9i64))]))],
        )
        .unwrap();
        merge_bench_json(&path, [("top", obj([("mid", obj([("b", Json::from(2i64))]))]))])
            .unwrap();
        // a leaf re-run still replaces its own deep value
        merge_bench_json(&path, [("top", obj([("mid", obj([("a", Json::from(7i64))]))]))])
            .unwrap();
        // ...and a non-object leaf replaces a deep object wholesale
        merge_bench_json(&path, [("top", obj([("keep", Json::from(10i64))]))]).unwrap();
        let v = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(v["top"]["mid"]["a"].as_i64(), Some(7), "deep leaf re-run overwrites");
        assert_eq!(v["top"]["mid"]["b"].as_i64(), Some(2), "deep sibling survives");
        assert_eq!(v["top"]["keep"].as_i64(), Some(10), "first-level sibling updated");
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print("test");
    }
}
