"""Pallas integer Softmax kernel (paper Figs. 11-12).

The ASIC instantiates one Softmax unit per row of Q·K^T and runs three
phases (max search, integer exp, divider).  The TPU mapping blocks over
rows: each grid step owns a (bm, n) row panel in VMEM and performs all
three phases on the VPU; the design-time constants q1..q3 (q_b, q_c,
q_ln2) are baked in.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..intops import SM_UNIT, SoftmaxConsts


def _softmax_kernel(q_ref, o_ref, *, q_ln2: int, q_b: int, q_c: int):
    q = q_ref[...].astype(jnp.int64)
    # Phase 1: per-row maximum search.
    qmax = jnp.max(q, axis=-1, keepdims=True)
    x = q - qmax  # <= 0
    # Phase 2: integer exp via ln2 decomposition + 2nd-order polynomial.
    z = (-x) // jnp.int64(q_ln2)
    r = x + z * jnp.int64(q_ln2)
    t = r + jnp.int64(q_b)
    poly = t * t + jnp.int64(q_c)
    e = poly >> jnp.clip(z, 0, 62)
    # Phase 3: rounding divider, output at scale 1/SM_UNIT.
    denom = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1)
    out = (e * jnp.int64(SM_UNIT) + (denom >> 1)) // denom
    o_ref[...] = jnp.clip(out, 0, SM_UNIT).astype(jnp.int32)


def _pick_block(dim: int, preferred: int) -> int:
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("consts", "bm"))
def i_softmax(q, consts: SoftmaxConsts, *, bm: int = 128):
    """Integer softmax along the last axis of an INT32 (m, n) tensor."""
    m, n = q.shape
    bm = _pick_block(m, bm)
    spec = pl.BlockSpec((bm, n), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(
            _softmax_kernel, q_ln2=consts.q_ln2, q_b=consts.q_b, q_c=consts.q_c
        ),
        grid=(m // bm,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(q)
