//! Reader for the flat binary tensor container the compile path writes
//! (`python/compile/blobs.py`): `<name>.bin` raw little-endian data plus
//! `<name>.json` index of `{dtype, shape, offset, nbytes}` entries.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub dtype: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

pub struct Blob {
    raw: Vec<u8>,
    index: BTreeMap<String, TensorMeta>,
}

impl Blob {
    /// Load `<prefix>.bin` + `<prefix>.json`.
    pub fn load(prefix: &Path) -> Result<Blob, String> {
        let json_path = prefix.with_extension("json");
        let bin_path = prefix.with_extension("bin");
        let idx_src = std::fs::read_to_string(&json_path)
            .map_err(|e| format!("read {}: {e}", json_path.display()))?;
        let raw = std::fs::read(&bin_path)
            .map_err(|e| format!("read {}: {e}", bin_path.display()))?;
        let parsed = Json::parse(&idx_src)?;
        let tensors = parsed.req("tensors")?.as_obj().ok_or("tensors not an object")?;
        let mut index = BTreeMap::new();
        for (name, e) in tensors {
            let meta = TensorMeta {
                dtype: e.req("dtype")?.as_str().ok_or("dtype")?.to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()
                    .ok_or("shape")?
                    .iter()
                    .map(|v| v.as_i64().unwrap_or(0) as usize)
                    .collect(),
                offset: e.req("offset")?.as_i64().ok_or("offset")? as usize,
                nbytes: e.req("nbytes")?.as_i64().ok_or("nbytes")? as usize,
            };
            if meta.offset + meta.nbytes > raw.len() {
                return Err(format!("tensor {name} overruns blob"));
            }
            index.insert(name.clone(), meta);
        }
        Ok(Blob { raw, index })
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }

    pub fn meta(&self, name: &str) -> Result<&TensorMeta, String> {
        self.index.get(name).ok_or_else(|| format!("no tensor {name:?} in blob"))
    }

    fn bytes(&self, name: &str) -> Result<(&TensorMeta, &[u8]), String> {
        let m = self.meta(name)?;
        Ok((m, &self.raw[m.offset..m.offset + m.nbytes]))
    }

    /// Read any integer tensor (i8 or i32 storage) widened to i32.
    pub fn i32(&self, name: &str) -> Result<Vec<i32>, String> {
        let (m, b) = self.bytes(name)?;
        match m.dtype.as_str() {
            "i8" => Ok(b.iter().map(|&v| v as i8 as i32).collect()),
            "i32" => Ok(b
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            other => Err(format!("tensor {name}: dtype {other} is not integer<=32")),
        }
    }

    pub fn i64(&self, name: &str) -> Result<Vec<i64>, String> {
        let (m, b) = self.bytes(name)?;
        match m.dtype.as_str() {
            "i64" => Ok(b
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                .collect()),
            _ => self.i32(name).map(|v| v.into_iter().map(|x| x as i64).collect()),
        }
    }

    pub fn f32(&self, name: &str) -> Result<Vec<f32>, String> {
        let (m, b) = self.bytes(name)?;
        if m.dtype != "f32" {
            return Err(format!("tensor {name}: dtype {} is not f32", m.dtype));
        }
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn shape(&self, name: &str) -> Result<&[usize], String> {
        Ok(&self.meta(name)?.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(dir: &Path, name: &str, json: &str, bin: &[u8]) {
        let mut f = std::fs::File::create(dir.join(format!("{name}.json"))).unwrap();
        f.write_all(json.as_bytes()).unwrap();
        let mut f = std::fs::File::create(dir.join(format!("{name}.bin"))).unwrap();
        f.write_all(bin).unwrap();
    }

    #[test]
    fn reads_i8_i32_f32() {
        let dir = std::env::temp_dir().join("swifttron_blob_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut bin = Vec::new();
        bin.extend_from_slice(&[0xFFu8, 1, 2]); // i8: [-1, 1, 2]
        bin.extend_from_slice(&7i32.to_le_bytes());
        bin.extend_from_slice(&1.5f32.to_le_bytes());
        let json = r#"{"tensors":{
            "a":{"dtype":"i8","shape":[3],"offset":0,"nbytes":3},
            "b":{"dtype":"i32","shape":[1],"offset":3,"nbytes":4},
            "c":{"dtype":"f32","shape":[1],"offset":7,"nbytes":4}}}"#;
        write_tmp(&dir, "t", json, &bin);
        let blob = Blob::load(&dir.join("t")).unwrap();
        assert_eq!(blob.i32("a").unwrap(), vec![-1, 1, 2]);
        assert_eq!(blob.i32("b").unwrap(), vec![7]);
        assert_eq!(blob.f32("c").unwrap(), vec![1.5]);
        assert_eq!(blob.shape("a").unwrap(), &[3]);
        assert!(blob.i32("zzz").is_err());
    }

    #[test]
    fn overrun_rejected() {
        let dir = std::env::temp_dir().join("swifttron_blob_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{"tensors":{"a":{"dtype":"i8","shape":[9],"offset":0,"nbytes":9}}}"#;
        write_tmp(&dir, "t", json, &[0u8; 4]);
        assert!(Blob::load(&dir.join("t")).is_err());
    }
}
