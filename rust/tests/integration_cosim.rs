//! Co-simulation: the rust functional model of the SwiftTron datapath
//! must agree **bit-for-bit** with the PJRT-executed Pallas artifact for
//! the roberta_base-shaped encoder layer — the same software-vs-RTL
//! validation triangle the paper runs with QuestaSim (§IV-B), closed
//! across three implementations (jnp spec == Pallas kernels == rust).
//!
//! Extended (ISSUE 4) with cross-model golden determinism: for every
//! geometry preset, the served outputs must be byte-identical across
//! 1/2/4-replica pools and across serial-vs-head-parallel attention.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;
use swifttron::coordinator::{
    EngineReplica, FunctionalEngine, Metrics, ReplicaPool, Request, SyntheticModel,
};
use swifttron::model::{Blob, Geometry, Manifest};
use swifttron::runtime::{Engine, Tensor};
use swifttron::sim::functional::{layer_forward, LayerWeights};
use swifttron::sim::HwConfig;
use swifttron::util::rng::Rng;

#[test]
fn pjrt_layer_matches_rust_functional_model_bit_exact() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping co-sim: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let preset = manifest.preset("roberta_base").unwrap();
    let geo = preset.geometry;
    let consts = &preset.layers[0]; // unified: every layer shares these

    let blob = Blob::load(&manifest.blob_prefix("roberta_base").unwrap()).unwrap();
    let w = LayerWeights::from_blob(&blob, 0).unwrap();

    // random INT8 input
    let mut rng = Rng::new(99);
    let q_x: Vec<i32> = (0..geo.m * geo.d).map(|_| rng.range_i64(-127, 127) as i32).collect();

    // rust functional model
    let rust_out = layer_forward(&q_x, &w, consts, &geo);

    // PJRT execution of the Pallas artifact (weights as arguments)
    let engine = Engine::cpu().unwrap();
    let exe = engine
        .load(&manifest.artifact_path("roberta_base", "int8_layer").unwrap())
        .unwrap();
    let mut inputs = vec![Tensor::i32(&[geo.m, geo.d], q_x)];
    for key in [
        "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo", "w1", "b1", "w2", "b2", "gamma1",
        "beta1", "gamma2", "beta2",
    ] {
        let data = blob.i32(&format!("L0.{key}")).unwrap();
        let shape = blob.shape(&format!("L0.{key}")).unwrap().to_vec();
        inputs.push(Tensor::i32(&shape, data));
    }
    let pjrt_out = exe.run_i32(&inputs, &[geo.m, geo.d]).unwrap();

    assert_eq!(
        pjrt_out.as_i32().unwrap(),
        &rust_out.q_out[..],
        "PJRT artifact and rust functional model diverged"
    );
}

#[test]
fn cross_model_outputs_are_deterministic_across_pools_and_attention_modes() {
    // For every preset: one shared synthetic weight bundle, a serial-
    // attention reference engine, and 1/2/4-replica pools of head-
    // parallel engines.  Labels, logits, and virtual time must be
    // byte-identical for every request regardless of which replica
    // served it or which attention execution path ran.  Heavy presets
    // run their exact d/heads/d_ff numerics at a test-sized depth and
    // sentence length — determinism does not depend on layer count, and
    // full-depth RoBERTa-large is minutes of host time.
    for preset in Geometry::PRESET_NAMES {
        let base = Geometry::preset(preset).unwrap();
        let geo = Geometry { layers: base.layers.min(2), m: base.m.min(16), ..base };
        let model = Arc::new(SyntheticModel::build_geo(&geo, 0xC0DE ^ preset.len() as u64));
        let hw_par = HwConfig::sized_to(&geo);
        let hw_serial = HwConfig { attn_heads_parallel: false, ..hw_par };
        let reference = FunctionalEngine::from_model(Arc::clone(&model), hw_serial);

        // duplicate lengths on purpose: identical requests must come
        // back identical from *different* replicas of one pool too
        let lens = [geo.m, geo.m, geo.m / 2, geo.m / 2, 1, 3.min(geo.m)];
        let streams: Vec<Vec<i32>> = lens
            .iter()
            .enumerate()
            .map(|(i, &l)| (0..l).map(|j| ((i * 11 + j * 5) % 60) as i32).collect())
            .collect();
        let want: Vec<_> = streams.iter().map(|t| reference.predict(t).unwrap()).collect();

        for replicas in [1usize, 2, 4] {
            let group: Vec<Arc<dyn EngineReplica>> = (0..replicas)
                .map(|_| {
                    Arc::new(FunctionalEngine::from_model(Arc::clone(&model), hw_par))
                        as Arc<dyn EngineReplica>
                })
                .collect();
            let metrics = Arc::new(Metrics::new());
            let pool = ReplicaPool::new(group, metrics);
            let mut receivers = Vec::new();
            let requests: Vec<Request> = streams
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    let (tx, rx) = channel();
                    receivers.push(rx);
                    Request {
                        id: i as u64,
                        model: 0,
                        tokens: t.clone(),
                        padded_len: t.len(),
                        cost: t.len() as u64,
                        submitted: Instant::now(),
                        origin: None,
                        reply: tx,
                    }
                })
                .collect();
            let responses = pool.dispatch(requests);
            for (i, resp) in responses.iter().enumerate() {
                let tag = format!("{preset} replicas={replicas} req {i}");
                assert!(resp.error.is_none(), "{tag}: {:?}", resp.error);
                assert_eq!(resp.label, want[i].label, "{tag}: label");
                assert_eq!(
                    resp.logits, want[i].logits,
                    "{tag}: head-parallel pool diverged from serial reference"
                );
                assert!(
                    (resp.accel_ms - want[i].accel_ms).abs() < 1e-12,
                    "{tag}: virtual time must not depend on host execution"
                );
            }
        }
    }
}

#[test]
fn multi_layer_stack_runs_and_stays_int8() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let preset = manifest.preset("roberta_base").unwrap();
    let geo = preset.geometry;
    let consts = preset.layers[0].clone();
    let blob = Blob::load(&manifest.blob_prefix("roberta_base").unwrap()).unwrap();

    let mut rng = Rng::new(5);
    let mut h: Vec<i32> = (0..geo.m * geo.d).map(|_| rng.range_i64(-127, 127) as i32).collect();
    // two layers through the rust functional model (full 12 reserved for
    // the example binary; tests stay fast)
    for layer in 0..2 {
        let w = LayerWeights::from_blob(&blob, layer).unwrap();
        let out = layer_forward(&h, &w, &consts, &geo);
        assert!(out.q_out.iter().all(|&v| (-128..=127).contains(&v)));
        assert!(out.sqrt_iters.iter().all(|&it| it <= 32));
        h = out.q_out;
    }
}
