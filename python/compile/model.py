"""L2: the Transformer encoder — float reference and integer-only twin.

The float model is the calibration source and the accuracy baseline; the
quantized model is built *exclusively* from the L1 integer operations and
follows the paper's Fig. 1b flow: INT8 MatMuls with INT32 accumulators,
INT32 nonlinearities, dyadic Requantization between blocks — never a
dequantize on the datapath.

``use_pallas=True`` routes every block through the Pallas kernels (the
configuration that gets AOT-lowered); ``use_pallas=False`` uses the
vectorized jnp spec in ``intops`` — the two are bit-identical (tested).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import intops
from .intops import SM_UNIT, Dyadic
from .quantize import Calibrator, QuantLayerParams


@dataclass(frozen=True)
class Geometry:
    """Transformer geometry (the paper's d, k, m, d_ff)."""

    d: int
    heads: int
    m: int
    d_ff: int
    layers: int

    @property
    def dh(self) -> int:
        return self.d // self.heads


# Presets used across the repo (paper §IV-B and Table II).
GEOMETRIES = {
    "tiny": Geometry(d=64, heads=4, m=32, d_ff=128, layers=2),
    "small": Geometry(d=128, heads=4, m=64, d_ff=512, layers=4),
    "roberta_base": Geometry(d=768, heads=12, m=256, d_ff=3072, layers=12),
    "roberta_large": Geometry(d=1024, heads=16, m=256, d_ff=4096, layers=24),
    "deit_s": Geometry(d=384, heads=6, m=197, d_ff=1536, layers=12),
}


# --- float reference model ----------------------------------------------------

def f_gelu(x):
    return x * 0.5 * (1.0 + jax.scipy.special.erf(x / math.sqrt(2.0)))


def f_gelu_tanh(x):
    """tanh-approximation GELU (BERT's formulation).  Used when lowering
    the float twin to HLO: xla_extension 0.5.1's parser predates the
    dedicated `erf` opcode; max deviation from exact GELU < 1e-3."""
    c = math.sqrt(2.0 / math.pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def f_layernorm(x, gamma, beta, eps=1e-12):
    mean = x.mean(-1, keepdims=True)
    var = ((x - mean) ** 2).mean(-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def float_encoder_layer(x, w, geo: Geometry, cal: Calibrator | None = None,
                        tag: str = "", gelu=f_gelu):
    """Float encoder layer (post-LN, BERT-style). ``w`` holds float arrays
    with the keys of ``quantize.design_layer``.  When ``cal`` is given,
    records max-abs statistics at every hardware requantization tap."""
    m, d = x.shape
    dh = geo.dh

    def obs(name, v):
        if cal is not None:
            cal.observe(f"{tag}.{name}", v)

    obs("x", x)
    q = x @ w["wq"] + w["bq"]
    k = x @ w["wk"] + w["bk"]
    v = x @ w["wv"] + w["bv"]
    obs("q", q); obs("k", k); obs("v", v)

    qh = q.reshape(m, geo.heads, dh).transpose(1, 0, 2)
    kh = k.reshape(m, geo.heads, dh).transpose(1, 0, 2)
    vh = v.reshape(m, geo.heads, dh).transpose(1, 0, 2)
    att = (qh @ kh.transpose(0, 2, 1)) / math.sqrt(dh)
    probs = jax.nn.softmax(att, axis=-1)
    ctx = (probs @ vh).transpose(1, 0, 2).reshape(m, d)
    obs("ctx", ctx)

    attn_out = ctx @ w["wo"] + w["bo"]
    res1 = x + attn_out
    ln1 = f_layernorm(res1, w["gamma1"], w["beta1"])
    obs("x2", ln1)
    obs("gamma1", w["gamma1"])

    h = gelu(ln1 @ w["w1"] + w["b1"])
    obs("h", h)
    ffn_out = h @ w["w2"] + w["b2"]
    res2 = ln1 + ffn_out
    out = f_layernorm(res2, w["gamma2"], w["beta2"])
    obs("out", out)
    obs("gamma2", w["gamma2"])
    return out


# --- integer-only model ---------------------------------------------------------

def _i_matmul(qx, qw, qb, use_pallas: bool):
    if use_pallas:
        from . import kernels

        return kernels.int_matmul(qx, qw, qb)
    return intops.i_matmul(qx, qw, qb)


def _i_requant(q, dy: Dyadic, use_pallas: bool):
    if use_pallas:
        from . import kernels

        return kernels.requantize(q, dy)
    return intops.requantize(q, dy)


def _i_softmax(q, consts, use_pallas: bool):
    if use_pallas:
        from . import kernels

        return kernels.i_softmax(q, consts)
    return intops.i_softmax(q, consts)


def _i_gelu(q, consts, use_pallas: bool):
    if use_pallas:
        from . import kernels

        return kernels.i_gelu(q, consts)
    return intops.i_gelu(q, consts)


def _i_layernorm(q, g, b, consts, use_pallas: bool):
    if use_pallas:
        from . import kernels

        return kernels.i_layernorm(q, g, b, consts)
    return intops.i_layernorm(q, g, b, consts)


def quant_encoder_layer(q_x, p: QuantLayerParams, geo: Geometry,
                        use_pallas: bool = True):
    """Integer-only encoder layer: INT8 input (stored INT32) -> INT8 output.

    Mirrors the SwiftTron block diagram (Figs. 5, 8-15): every arrow in the
    hardware is one call here, every Req block one ``requantize``.
    """
    m, d = q_x.shape
    dh = geo.dh

    # --- MHSA: Q/K/V projections (MatMul blocks + Req units) ---
    q8 = _i_requant(_i_matmul(q_x, p.wq, p.bq, use_pallas), p.dy_q, use_pallas)
    k8 = _i_requant(_i_matmul(q_x, p.wk, p.bk, use_pallas), p.dy_k, use_pallas)
    v8 = _i_requant(_i_matmul(q_x, p.wv, p.bv, use_pallas), p.dy_v, use_pallas)

    # --- Attention per head (Fig. 10): MatMul -> Scale -> Softmax -> Req -> MatMul
    ctx_heads = []
    for h in range(geo.heads):
        sl = slice(h * dh, (h + 1) * dh)
        qh, kh, vh = q8[:, sl], k8[:, sl], v8[:, sl]
        scores = _i_matmul(qh, kh.T, None, use_pallas)          # INT32
        scaled = intops.rescale(scores, p.dy_scale)             # Scale block
        probs = _i_softmax(scaled, p.sm, use_pallas)            # INT8 @ 1/127
        ctx_heads.append(_i_matmul(probs, vh, None, use_pallas))
    ctx_acc = jnp.concatenate(ctx_heads, axis=-1)               # INT32
    ctx8 = _i_requant(ctx_acc, p.dy_ctx, use_pallas)

    # --- output projection + residual align (Dyadic) + LayerNorm 1 ---
    attn_acc = _i_matmul(ctx8, p.wo, p.bo, use_pallas)
    res1 = q_x + intops.rescale(attn_acc, p.dy_res1)            # both @ s_x
    ln1 = _i_layernorm(res1, p.gamma1, p.beta1, p.ln1, use_pallas)
    x2 = _i_requant(ln1, p.dy_ln1, use_pallas)                  # INT8 @ s_x2

    # --- FFN (Fig. 13): MatMul -> GELU -> Req -> MatMul ---
    h_acc = _i_matmul(x2, p.w1, p.b1, use_pallas)
    g = _i_gelu(h_acc, p.gelu, use_pallas)                      # INT64
    # GELU's integer output scale s_in*s_erf/2 is *negative* (the erf
    # polynomial's leading coefficient a < 0 is folded into the scale), so
    # the requantization multiplier is the signed constant -b.
    h8 = _i_requant_wide(g, p.dy_gelu, sign=-1)
    ffn_acc = _i_matmul(h8, p.w2, p.b2, use_pallas)

    # --- residual align + LayerNorm 2 + output Req ---
    res2 = x2 + intops.rescale(ffn_acc, p.dy_res2)              # both @ s_x2
    ln2 = _i_layernorm(res2, p.gamma2, p.beta2, p.ln2, use_pallas)
    return _i_requant(ln2, p.dy_ln2, use_pallas)                # INT8 @ s_out


def _i_requant_wide(q64, dy: Dyadic, sign: int = 1):
    """Requantize an INT64 GELU product (vectorized jnp; the Pallas requant
    kernel tiles INT32 — the wide product is a single multiply+shift and
    XLA fuses it with the kernel output).  ``sign=-1`` multiplies by the
    signed hardware constant -b (negative-scale inputs)."""
    shifted = (q64 * jnp.int64(sign * dy.b)) >> jnp.int64(dy.c)
    return jnp.clip(shifted, -128, 127).astype(jnp.int32)


def quant_encoder(q_x, layers: list[QuantLayerParams], geo: Geometry,
                  use_pallas: bool = True):
    """Full integer encoder stack: INT8 in, INT8 out."""
    h = q_x
    for p in layers:
        h = quant_encoder_layer(h, p, geo, use_pallas=use_pallas)
    return h


def float_encoder(x, weights: list[dict], geo: Geometry,
                  cal: Calibrator | None = None):
    h = x
    for i, w in enumerate(weights):
        h = float_encoder_layer(h, w, geo, cal=cal, tag=f"L{i}")
    return h


# --- weight initialization (random, layer-scale-realistic) ----------------------

def init_layer_weights(rng: np.random.Generator, geo: Geometry) -> dict:
    """Random float weights with transformer-realistic magnitudes."""
    d, dff = geo.d, geo.d_ff
    s = 1.0 / math.sqrt(d)
    return {
        "wq": rng.normal(0, s, (d, d)), "bq": rng.normal(0, 0.02, (d,)),
        "wk": rng.normal(0, s, (d, d)), "bk": rng.normal(0, 0.02, (d,)),
        "wv": rng.normal(0, s, (d, d)), "bv": rng.normal(0, 0.02, (d,)),
        "wo": rng.normal(0, s, (d, d)), "bo": rng.normal(0, 0.02, (d,)),
        "w1": rng.normal(0, s, (d, dff)), "b1": rng.normal(0, 0.02, (dff,)),
        "w2": rng.normal(0, 1.0 / math.sqrt(dff), (dff, d)),
        "b2": rng.normal(0, 0.02, (d,)),
        "gamma1": rng.normal(1.0, 0.05, (d,)), "beta1": rng.normal(0, 0.05, (d,)),
        "gamma2": rng.normal(1.0, 0.05, (d,)), "beta2": rng.normal(0, 0.05, (d,)),
    }


def init_encoder_weights(seed: int, geo: Geometry) -> list[dict]:
    rng = np.random.default_rng(seed)
    return [init_layer_weights(rng, geo) for _ in range(geo.layers)]
