//! Per-block timing models (cycles), one function per hardware unit.
//!
//! Each formula states its dataflow assumption next to the paper figure
//! it models.  All counts are in clock cycles of the unit's own schedule;
//! the encoder-level FSMs (`control`/`encoder`) sequence them.

use super::HwConfig;
use crate::quant::layernorm::ISQRT_MAX_ITERS;

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// MatMul block (Fig. 6): output-stationary R x C MAC array computing an
/// (M,K) x (K,N) product.  Each (R,C) output tile is loaded by streaming
/// the K operand panels (one cycle per k step) and drained column-by-
/// column through the output multiplexer (one cycle per occupied column).
pub fn matmul_cycles(cfg: &HwConfig, m: usize, k: usize, n: usize) -> u64 {
    let tiles_r = ceil_div(m, cfg.array_rows);
    let tiles_c = ceil_div(n, cfg.array_cols);
    let readout = n.min(cfg.array_cols) as u64;
    (tiles_r as u64) * (tiles_c as u64) * (k as u64 + readout)
}

/// MatMul against *resident weights* (the Q/K/V/output projections and
/// both FFN matmuls): the weight port streams `8 / weight_bits` packed
/// k-panels per weight-SRAM word, so the feed phase of each tile takes
/// `ceil(k / packs)` cycles — `k` at INT8, `ceil(k/2)` at the packed
/// INT4 tier (DESIGN.md §14).  Readout is accumulator-width-bound and
/// unchanged.  Activation-activation matmuls (Q.K^T, P.V) never touch
/// the weight port and keep [`matmul_cycles`].
pub fn weight_matmul_cycles(cfg: &HwConfig, m: usize, k: usize, n: usize) -> u64 {
    let packs = (8 / cfg.weight_bits.max(1)).max(1) as usize;
    let tiles_r = ceil_div(m, cfg.array_rows);
    let tiles_c = ceil_div(n, cfg.array_cols);
    let readout = n.min(cfg.array_cols) as u64;
    (tiles_r as u64) * (tiles_c as u64) * (ceil_div(k, packs) as u64 + readout)
}

/// Utilization of the MAC array for an (M,K)x(K,N) product: useful MACs
/// over MACs offered during the feed phase (readout excluded).
pub fn matmul_utilization(cfg: &HwConfig, m: usize, k: usize, n: usize) -> f64 {
    let useful = (m * k * n) as f64;
    let tiles_r = ceil_div(m, cfg.array_rows);
    let tiles_c = ceil_div(n, cfg.array_cols);
    let offered = (tiles_r * cfg.array_rows * tiles_c * cfg.array_cols * k) as f64;
    useful / offered
}

/// Softmax unit (Figs. 11-12): one unit per matrix row (m instances work
/// concurrently, §III-F); each unit scans its n-element row three times
/// (max search, exp + sum, divider) with `pipeline_stages` fill cycles.
/// Rows beyond the instantiated unit count serialize in waves.
pub fn softmax_cycles(cfg: &HwConfig, rows: usize, n: usize) -> u64 {
    let waves = ceil_div(rows, cfg.softmax_units) as u64;
    let per_row = 3 * n as u64 + cfg.pipeline_stages;
    waves * per_row
}

/// LayerNorm unit (Fig. 15): d element-parallel lanes hold one row;
/// mean and variance are lane-tree reductions (log2 d levels), the sqrt
/// iterates (worst case by default, footnote 3), then every lane applies
/// divider + affine.  Rows stream through the 3-stage pipeline.
pub fn layernorm_row_cycles(cfg: &HwConfig, d: usize, sqrt_iters: u32) -> u64 {
    let lanes = cfg.layernorm_lanes.min(d).max(1);
    let chunks = ceil_div(d, lanes) as u64;
    let tree = (usize::BITS - (lanes - 1).leading_zeros()) as u64; // ceil(log2)
    let mean = chunks + tree;
    let iters = if cfg.worst_case_sqrt { ISQRT_MAX_ITERS } else { sqrt_iters } as u64;
    // variance needs the subtract+square pass plus the same reduction
    let var = chunks + tree + 1;
    let output = chunks + 1; // divider + affine per lane, chunked
    mean + var + iters + output + cfg.pipeline_stages
}

/// Full LayerNorm over (rows x d): rows stream through the pipeline —
/// after the first row fills the pipe, one row completes per stage time;
/// we charge the conservative non-overlapped bound divided by stages.
pub fn layernorm_cycles(cfg: &HwConfig, rows: usize, d: usize, sqrt_iters: &[u32]) -> u64 {
    let default_iters = ISQRT_MAX_ITERS;
    (0..rows)
        .map(|r| {
            let it = sqrt_iters.get(r).copied().unwrap_or(default_iters);
            layernorm_row_cycles(cfg, d, it)
        })
        .sum::<u64>()
        / cfg.pipeline_stages
}

/// GELU unit (Fig. 14): combinational polynomial lanes sized to the
/// producing MatMul's readout width; it consumes columns as they drain,
/// so only the pipeline fill is charged.
pub fn gelu_cycles(cfg: &HwConfig) -> u64 {
    cfg.pipeline_stages
}

/// Requantization unit (Fig. 7): one multiplier+shifter per readout lane,
/// fully overlapped with the producer; pipeline fill only.
pub fn requant_cycles(cfg: &HwConfig) -> u64 {
    cfg.pipeline_stages
}

/// Residual-alignment Dyadic unit (§III-I): replicated per row, consumes
/// one column per cycle — overlapped, pipeline fill only.
pub fn residual_cycles(cfg: &HwConfig) -> u64 {
    cfg.pipeline_stages
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HwConfig {
        HwConfig::paper()
    }

    #[test]
    fn matmul_single_tile() {
        // 256x768 array, (256,768)x(768,768): one row tile, one col tile
        assert_eq!(matmul_cycles(&cfg(), 256, 768, 768), 768 + 768);
    }

    #[test]
    fn weight_matmul_at_8_bits_is_plain_matmul() {
        let c = cfg();
        for (m, k, n) in [(256usize, 768usize, 768usize), (32, 768, 3072), (1, 1, 1)] {
            assert_eq!(weight_matmul_cycles(&c, m, k, n), matmul_cycles(&c, m, k, n));
        }
    }

    #[test]
    fn weight_matmul_at_4_bits_halves_the_feed_phase() {
        let c8 = cfg();
        let c4 = HwConfig { weight_bits: 4, ..c8 };
        // one tile, k=768, readout=768: feed halves, readout stays
        assert_eq!(weight_matmul_cycles(&c4, 256, 768, 768), 384 + 768);
        // odd contraction depth rounds the packed feed up
        assert_eq!(weight_matmul_cycles(&c4, 256, 7, 768), 4 + 768);
    }

    #[test]
    fn matmul_tiling_multiplies() {
        // N = 3072 -> 4 column tiles
        let one = matmul_cycles(&cfg(), 256, 768, 768);
        let four = matmul_cycles(&cfg(), 256, 768, 3072);
        assert_eq!(four, 4 * one);
    }

    #[test]
    fn matmul_small_problem_underutilizes() {
        let u_full = matmul_utilization(&cfg(), 256, 768, 768);
        let u_half = matmul_utilization(&cfg(), 197, 384, 384);
        assert!((u_full - 1.0).abs() < 1e-12);
        assert!(u_half < 0.5);
    }

    #[test]
    fn softmax_row_waves() {
        let c = cfg();
        let one_wave = softmax_cycles(&c, 256, 256);
        let two_waves = softmax_cycles(&c, 512, 256);
        assert_eq!(two_waves, 2 * one_wave);
        assert_eq!(one_wave, 3 * 256 + c.pipeline_stages);
    }

    #[test]
    fn layernorm_worst_case_ge_data_dependent() {
        let mut c = cfg();
        c.worst_case_sqrt = true;
        let wc = layernorm_cycles(&c, 256, 768, &vec![5; 256]);
        c.worst_case_sqrt = false;
        let dd = layernorm_cycles(&c, 256, 768, &vec![5; 256]);
        assert!(wc > dd);
    }

    #[test]
    fn overlapped_units_charge_fill_only() {
        let c = cfg();
        assert_eq!(gelu_cycles(&c), c.pipeline_stages);
        assert_eq!(requant_cycles(&c), c.pipeline_stages);
        assert_eq!(residual_cycles(&c), c.pipeline_stages);
    }
}
