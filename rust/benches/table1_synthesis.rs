//! Table I — synthesis summary of the complete SwiftTron architecture
//! (paper: 143 MHz, 65 nm, 33.64 W, 273.0 mm^2 at d=768, k=12, m=256,
//! d_ff=3072).  Regenerated from the gate-level cost model + simulator.

use swifttron::model::Geometry;
use swifttron::sim::HwConfig;
use swifttron::synthesis::synthesis_report;
use swifttron::util::bench::Table;

fn main() {
    let cfg = HwConfig::paper();
    let geo = Geometry::preset("roberta_base").unwrap();
    let r = synthesis_report(&cfg, &geo);

    let mut t = Table::new(&["metric", "paper", "this model"]);
    t.row(&["Clock Frequency".into(), "143 MHz".into(), format!("{:.0} MHz", r.clock_mhz)]);
    t.row(&["Technology Node".into(), "65 nm".into(), r.tech_node.to_string()]);
    t.row(&["Power Consumption".into(), "33.64 W".into(), format!("{:.2} W", r.power_w)]);
    t.row(&["Area".into(), "273.0 mm^2".into(), format!("{:.1} mm^2", r.area_mm2)]);
    t.row(&[
        "Critical path".into(),
        "<= 7 ns (meets timing)".into(),
        format!("{:.2} ns", r.critical_path_ns),
    ]);
    t.print("Table I — SwiftTron synthesis summary");
    println!(
        "\nshape check: same order of magnitude for area and power; timing met at 7 ns: {}",
        r.critical_path_ns <= 7.0
    );
}
