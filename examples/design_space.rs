//! Design-space exploration: the paper stresses that array size, head
//! parallelism, and clock are design-time tunables (§III-D); this
//! example sweeps them and prints the latency/area/power Pareto table
//! for RoBERTa-base — the study an adopter would run before taping out.
//!
//! Run: `cargo run --release --example design_space`

use swifttron::model::Geometry;
use swifttron::sim::{simulate_encoder, HwConfig};
use swifttron::synthesis::synthesis_report;
use swifttron::util::bench::Table;

fn main() {
    let geo = Geometry::preset("roberta_base").unwrap();
    let mut table = Table::new(&[
        "array", "heads", "latency ms", "area mm^2", "power W", "lat*area (norm)",
    ]);
    let paper = HwConfig::paper();
    let base_cost = {
        let r = simulate_encoder(&paper, &geo);
        let s = synthesis_report(&paper, &geo);
        r.ms(&paper) * s.area_mm2
    };

    for (rows, cols) in [(64, 256), (128, 384), (128, 768), (256, 768), (256, 1536)] {
        for ph in [4, 12] {
            let cfg = HwConfig {
                array_rows: rows,
                array_cols: cols,
                parallel_heads: ph,
                softmax_units: rows,
                layernorm_lanes: cols,
                ..paper
            };
            if cfg.validate(&geo).is_err() {
                continue;
            }
            let sim = simulate_encoder(&cfg, &geo);
            let synth = synthesis_report(&cfg, &geo);
            let ms = sim.ms(&cfg);
            table.row(&[
                format!("{rows}x{cols}"),
                format!("{ph}"),
                format!("{ms:.2}"),
                format!("{:.0}", synth.area_mm2),
                format!("{:.1}", synth.power_w),
                format!("{:.2}", ms * synth.area_mm2 / base_cost),
            ]);
        }
    }
    table.print("SwiftTron design space — RoBERTa-base (paper config = 256x768/12)");
    println!("\nnote: the paper's §IV-B instance is the 256x768, 12-head row.");
}
