//! Cross-language golden-vector tests: the rust `quant` primitives must
//! reproduce the python oracle outputs (artifacts/golden.{bin,json})
//! bit-for-bit.  This is the contract that makes the rust functional
//! model, the Pallas kernels, and the jnp spec one arithmetic.

use swifttron::model::Blob;
use swifttron::quant::{
    i_exp, i_gelu, i_layernorm, i_softmax, i_sqrt, requantize, Dyadic, GeluConsts,
    LayerNormConsts, SoftmaxConsts,
};
use swifttron::util::json::Json;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = swifttron::model::Manifest::default_dir();
    if dir.join("golden.bin").exists() {
        Some(dir)
    } else {
        eprintln!("skipping golden tests: run `make artifacts` first");
        None
    }
}

fn load(dir: &std::path::Path) -> (Blob, Json) {
    let blob = Blob::load(&dir.join("golden")).expect("golden blob");
    let consts =
        Json::parse(&std::fs::read_to_string(dir.join("golden_consts.json")).unwrap()).unwrap();
    (blob, consts)
}

#[test]
fn golden_requantize() {
    let Some(dir) = artifacts() else { return };
    let (blob, consts) = load(&dir);
    let dy = Dyadic {
        b: consts["requant"]["b"].as_i64().unwrap(),
        c: consts["requant"]["c"].as_i64().unwrap() as u32,
    };
    let input = blob.i64("requant_in").unwrap();
    let want = blob.i32("requant_out").unwrap();
    let got: Vec<i32> = input.iter().map(|&q| requantize(q, dy)).collect();
    assert_eq!(got, want);
}

#[test]
fn golden_softmax_and_exp() {
    let Some(dir) = artifacts() else { return };
    let (blob, consts) = load(&dir);
    let c = SoftmaxConsts {
        s_in: consts["softmax"]["s_in"].as_f64().unwrap(),
        q_ln2: consts["softmax"]["q_ln2"].as_i64().unwrap(),
        q_b: consts["softmax"]["q_b"].as_i64().unwrap(),
        q_c: consts["softmax"]["q_c"].as_i64().unwrap(),
    };
    // i_exp
    let xin = blob.i64("iexp_in").unwrap();
    let want = blob.i64("iexp_out").unwrap();
    let got: Vec<i64> = xin.iter().map(|&x| i_exp(x, &c)).collect();
    assert_eq!(got, want, "i_exp mismatch");
    // softmax rows
    let rows = blob.shape("softmax_in").unwrap()[0];
    let n = blob.shape("softmax_in").unwrap()[1];
    let qin = blob.i32("softmax_in").unwrap();
    let want = blob.i32("softmax_out").unwrap();
    let mut got = vec![0i32; rows * n];
    for r in 0..rows {
        let row: Vec<i64> = qin[r * n..(r + 1) * n].iter().map(|&v| v as i64).collect();
        i_softmax(&row, &c, &mut got[r * n..(r + 1) * n]);
    }
    assert_eq!(got, want, "softmax mismatch");
}

#[test]
fn golden_gelu() {
    let Some(dir) = artifacts() else { return };
    let (blob, consts) = load(&dir);
    let c = GeluConsts {
        s_in: consts["gelu"]["s_in"].as_f64().unwrap(),
        q_b: consts["gelu"]["q_b"].as_i64().unwrap(),
        q_c: consts["gelu"]["q_c"].as_i64().unwrap(),
        q_one: consts["gelu"]["q_one"].as_i64().unwrap(),
    };
    let qin = blob.i32("gelu_in").unwrap();
    let want = blob.i64("gelu_out").unwrap();
    let got: Vec<i64> = qin.iter().map(|&q| i_gelu(q as i64, &c)).collect();
    assert_eq!(got, want);
}

#[test]
fn golden_layernorm() {
    let Some(dir) = artifacts() else { return };
    let (blob, consts) = load(&dir);
    let d = consts["layernorm"]["d"].as_i64().unwrap() as usize;
    let c = LayerNormConsts {
        s_in: consts["layernorm"]["s_in"].as_f64().unwrap(),
        s_gamma: consts["layernorm"]["s_gamma"].as_f64().unwrap(),
        d,
    };
    let rows = blob.shape("ln_in").unwrap()[0];
    let qin = blob.i64("ln_in").unwrap();
    let gamma = blob.i64("ln_gamma").unwrap();
    let beta = blob.i64("ln_beta").unwrap();
    let want = blob.i32("ln_out").unwrap();
    let mut got = vec![0i32; rows * d];
    for r in 0..rows {
        i_layernorm(&qin[r * d..(r + 1) * d], &gamma, &beta, &c, &mut got[r * d..(r + 1) * d]);
    }
    assert_eq!(got, want);
}

#[test]
fn golden_isqrt_values_and_iteration_counts() {
    let Some(dir) = artifacts() else { return };
    let (blob, _) = load(&dir);
    let ns = blob.i64("isqrt_in").unwrap();
    let want_v = blob.i64("isqrt_out").unwrap();
    let want_it = blob.i32("isqrt_iters").unwrap();
    for (i, &n) in ns.iter().enumerate() {
        let (v, it) = i_sqrt(n);
        assert_eq!(v, want_v[i], "isqrt({n})");
        assert_eq!(it as i32, want_it[i], "isqrt iters({n}) — simulator timing contract");
    }
}
