//! Minimal JSON parser + writer (no serde in the offline crate set).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated.  Numbers parse as f64 with an i64 fast path, which
//! is sufficient for the artifact manifests this repo produces.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style chained lookup; errors name the missing key.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- writer ----------------------------------------------------------

    #[allow(clippy::inherent_to_string_shadow_display)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e18 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::ops::Index<&str> for Json {
    type Output = Json;
    /// Panicking object lookup — handy in tests and report code.
    fn index(&self, k: &str) -> &Json {
        self.get(k).unwrap_or_else(|| panic!("missing key {k:?}"))
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&Json::to_string(self))
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

/// Convenience builder: `obj([("a", 1.into()), ...])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(items.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":"hi\n","d":true},"e":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "x", "a": [1], "f": 1.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_i64(), Some(42));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("f").unwrap().as_i64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse(r#"{"a":1} extra"#).is_err());
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\cA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\cA"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo – ≤""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo – ≤"));
    }

    #[test]
    fn builder_writes_sorted_keys() {
        let v = obj([("b", 1i64.into()), ("a", 2i64.into())]);
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
