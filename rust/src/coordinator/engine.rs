//! Inference engine for the tiny-task model: the end-to-end request path.
//!
//! Request path (all integer once quantized, paper Fig. 1b):
//!   tokens -> embedding + positional add (host f32, outside the
//!   accelerator per Fig. 4's "inputs taken after positional encoding")
//!   -> symmetric INT8 quantization at the calibrated `s_in`
//!   -> PJRT execution of the AOT integer encoder artifact
//!   -> integer mean-pool + INT8 classifier head (rust `quant::i_matmul`)
//!   -> argmax label.
//!
//! Each prediction also carries the cycle-accurate SwiftTron latency for
//! the same computation (the coordinator's virtual-time accounting).

use crate::model::{Blob, Geometry, Manifest};
use crate::quant::i_matmul;
use crate::runtime::{Engine, Executable, Tensor};
use crate::sim::{simulate_encoder, HwConfig};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Prediction {
    pub label: usize,
    pub logits: Vec<i64>,
    /// simulated accelerator latency for this inference
    pub accel_cycles: u64,
    pub accel_ms: f64,
}

pub struct InferenceEngine {
    pub geo: Geometry,
    exe_int8: Executable,
    exe_f32: Option<Executable>,
    emb: Vec<f32>,    // (vocab, d)
    pos: Vec<f32>,    // (m, d)
    q_w_head: Vec<i32>, // (d, 2)
    q_b_head: Vec<i32>,
    f_w_head: Vec<f32>,
    f_b_head: Vec<f32>,
    s_in: f64,
    vocab: usize,
    hw: HwConfig,
    accel_cycles: u64,
}

impl InferenceEngine {
    /// Build from the artifacts directory (tiny preset).
    pub fn load(artifacts: &Path, engine: &Engine, hw: HwConfig) -> Result<InferenceEngine, String> {
        let manifest = Manifest::load(artifacts)?;
        let preset = manifest.preset("tiny")?;
        let geo = preset.geometry;
        let blob = Blob::load(&manifest.blob_prefix("tiny")?)?;
        let exe_int8 = engine.load(&manifest.artifact_path("tiny", "int8")?)?;
        let exe_f32 = manifest
            .artifact_path("tiny", "f32")
            .ok()
            .and_then(|p| engine.load(&p).ok());
        let sim = simulate_encoder(&hw, &geo);
        Ok(InferenceEngine {
            geo,
            exe_int8,
            exe_f32,
            emb: blob.f32("emb")?,
            pos: blob.f32("pos")?,
            q_w_head: blob.i32("q_w_head")?,
            q_b_head: blob.i32("q_b_head")?,
            f_w_head: blob.f32("f_w_head")?,
            f_b_head: blob.f32("f_b_head")?,
            s_in: preset.s_in.ok_or("tiny preset missing s_in")?,
            vocab: blob.shape("emb")?[0],
            hw,
            accel_cycles: sim.total_cycles,
        })
    }

    /// Embedding + positional add + INT8 quantization (host side).
    pub fn embed_quantize(&self, tokens: &[i32]) -> Result<Vec<i32>, String> {
        let (m, d) = (self.geo.m, self.geo.d);
        if tokens.len() != m {
            return Err(format!("expected {m} tokens, got {}", tokens.len()));
        }
        let mut q = vec![0i32; m * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.vocab {
                return Err(format!("token {t} out of vocab {}", self.vocab));
            }
            for j in 0..d {
                let x = self.emb[t * d + j] as f64 + self.pos[i * d + j] as f64;
                q[i * d + j] = (x / self.s_in).round().clamp(-128.0, 127.0) as i32;
            }
        }
        Ok(q)
    }

    /// Integer mean-pool (shift when m is a power of two) + INT8 head.
    fn head(&self, q_out: &[i32]) -> (usize, Vec<i64>) {
        let (m, d) = (self.geo.m, self.geo.d);
        let mut pooled = vec![0i32; d];
        for j in 0..d {
            let mut s: i64 = 0;
            for i in 0..m {
                s += q_out[i * d + j] as i64;
            }
            pooled[j] = crate::quant::div_floor(s, m as i64) as i32;
        }
        let n_cls = self.q_b_head.len();
        let mut logits32 = vec![0i32; n_cls];
        i_matmul(&pooled, &self.q_w_head, Some(&self.q_b_head), 1, d, n_cls, &mut logits32);
        let logits: Vec<i64> = logits32.iter().map(|&v| v as i64).collect();
        let label = (0..n_cls).max_by_key(|&i| logits[i]).unwrap_or(0);
        (label, logits)
    }

    /// Full integer-path prediction via the PJRT artifact.
    pub fn predict(&self, tokens: &[i32]) -> Result<Prediction, String> {
        let (m, d) = (self.geo.m, self.geo.d);
        let q_x = self.embed_quantize(tokens)?;
        let out = self.exe_int8.run_i32(&[Tensor::i32(&[m, d], q_x)], &[m, d])?;
        let (label, logits) = self.head(out.as_i32().unwrap());
        Ok(Prediction {
            label,
            logits,
            accel_cycles: self.accel_cycles,
            accel_ms: self.hw.cycles_to_ms(self.accel_cycles),
        })
    }

    /// Float-twin prediction (accuracy baseline).
    pub fn predict_f32(&self, tokens: &[i32]) -> Result<usize, String> {
        let exe = self.exe_f32.as_ref().ok_or("no f32 artifact")?;
        let (m, d) = (self.geo.m, self.geo.d);
        let mut x = vec![0f32; m * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            for j in 0..d {
                x[i * d + j] = self.emb[t * d + j] + self.pos[i * d + j];
            }
        }
        let out = exe.run_f32(&[Tensor::f32(&[m, d], x)], &[m, d])?;
        let h = out.as_f32().unwrap();
        let n_cls = self.f_b_head.len();
        let mut pooled = vec![0f64; d];
        for j in 0..d {
            pooled[j] = (0..m).map(|i| h[i * d + j] as f64).sum::<f64>() / m as f64;
        }
        let mut logits = vec![0f64; n_cls];
        for (c, l) in logits.iter_mut().enumerate() {
            *l = self.f_b_head[c] as f64
                + (0..d).map(|j| pooled[j] * self.f_w_head[j * n_cls + c] as f64).sum::<f64>();
        }
        Ok((0..n_cls).max_by_key(|&i| (logits[i] * 1e9) as i64).unwrap_or(0))
    }

    pub fn hw(&self) -> &HwConfig {
        &self.hw
    }
}
